//! Figure 3: Needle-in-a-Haystack heatmaps — accuracy over (context length,
//! needle depth) for the five inference strategies. Rendered as text
//! heatmaps + CSV.

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::metrics::token_f1;
use crate::kvcache::ChunkStore;
use crate::pipeline::Pipeline;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::needle::needle_episode;

pub const DEPTHS: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

/// Mean needle F1 for one (method, n_chunks, depth) cell.
pub fn needle_cell(
    pipeline: &Pipeline,
    store: &ChunkStore,
    method: MethodSpec,
    n_chunks: usize,
    depth: f64,
    samples: usize,
    seed: u64,
) -> Result<f64> {
    let chunk = pipeline.session.runtime.manifest.model.chunk;
    let mut rng = Rng::new(seed ^ ((n_chunks as u64) << 32) ^ ((depth * 100.0) as u64));
    let mut f1 = 0.0;
    for _ in 0..samples {
        let e = needle_episode(&pipeline.vocab, chunk, &mut rng, n_chunks, depth);
        let (chunks, _) = pipeline.prepare_chunks(store, &e.chunks)?;
        let r = pipeline.answer(&chunks, &e.prompt, method)?;
        f1 += token_f1(&r.answer, &e.answer);
    }
    Ok(f1 / samples as f64)
}

pub fn shade(x: f64) -> char {
    match x {
        x if x >= 0.9 => '#',
        x if x >= 0.7 => '@',
        x if x >= 0.5 => '+',
        x if x >= 0.3 => ':',
        x if x >= 0.1 => '.',
        _ => ' ',
    }
}

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let backbone = ctx.backbone_or_default(args);
    let pipeline = ctx.pipeline(&backbone)?;
    let budget = args.usize_or("budget", 16)?;
    let lengths: Vec<usize> = vec![2, 4, 6, 8]; // chunks => 128..512 tokens

    let methods: Vec<(String, MethodSpec)> = vec![
        ("Baseline".into(), MethodSpec::Baseline),
        ("No Recompute".into(), MethodSpec::NoRecompute),
        ("Our".into(), MethodSpec::ours(budget)),
        ("Our + Reorder".into(), MethodSpec::ours_reorder(budget)),
        ("CacheBlend".into(), MethodSpec::CacheBlend { budget }),
        ("EPIC".into(), MethodSpec::Epic { budget }),
    ];

    let chunk = ctx.runtime.manifest.model.chunk;
    let mut json_rows = vec![];
    let mut csv = String::from("method,ctx_tokens,depth,f1\n");
    for (mname, method) in &methods {
        println!("\n-- Needle heatmap: {mname} ({backbone}) --");
        println!("        depth:   0.00  0.25  0.50  0.75  1.00");
        for &n_chunks in &lengths {
            let store = ctx.store();
            let mut row = format!("ctx {:>4} tok  |", n_chunks * chunk);
            for &depth in &DEPTHS {
                let f1 = needle_cell(
                    &pipeline, &store, *method, n_chunks, depth,
                    ctx.samples.min(12), ctx.seed,
                )?;
                row.push_str(&format!("  {:.2}{}", f1, shade(f1)));
                csv.push_str(&format!("{mname},{},{depth},{f1:.4}\n", n_chunks * chunk));
                json_rows.push(Json::obj(vec![
                    ("method", Json::from(mname.as_str())),
                    ("ctx_tokens", Json::from(n_chunks * chunk)),
                    ("depth", Json::from(depth)),
                    ("f1", Json::from(f1)),
                ]));
            }
            println!("{row}");
        }
    }
    ctx.dump("fig3", Json::Arr(json_rows), Some(csv))?;
    Ok(())
}
