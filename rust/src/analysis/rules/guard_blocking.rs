//! L1 `guard-across-blocking` — a `Mutex`/`RwLock` guard whose live scope
//! contains a call that can block (channel recv, thread join, queue pop,
//! model/pipeline entry points, file I/O).
//!
//! The PR-1 bug class: `answer` was called with a registry lock held,
//! serializing the whole worker pool behind one query.  The rule models
//! Rust's guard lifetimes (named `let` bindings to end of block, match-
//! scrutinee temporaries through the whole match, condition temporaries
//! dying at the `{`, plain temporaries at the `;`) and flags any blocking
//! call lexically inside the live region.
//!
//! Since the interprocedural upgrade, "blocking" is the transitive
//! may-block set from `analysis::callgraph` (seeded by the direct list
//! below), so a guard held across a helper that eventually calls `recv`
//! three frames down is flagged with the full witness chain.  A fn marked
//! `// lint:nonblocking(reason="…")` is excluded from the set.

use super::super::callgraph::CallGraph;
use super::super::lexer::{Tok, TokKind};
use super::super::scope::{
    block_after, classify_guard_context, enclosing_block_end, in_regions, stmt_end, GuardCtx,
    Region,
};
use super::super::symbols::SymbolTable;
use super::{args_empty, is_call, is_method_call, receiver_name, GUARD_ACROSS_BLOCKING};
use crate::analysis::Diag;

/// Methods whose zero-arg poisoning-propagating call produces a guard.
const GUARD_FNS: [&str; 4] = ["lock", "read", "write", "lock_shard"];

/// How a blocklist entry matches.
enum Mode {
    /// Any call by this name.
    Any,
    /// Only zero-argument calls (disambiguates `JoinHandle::join()` from
    /// `Path::join(x)`, `FlightSlot::wait()` from `Condvar::wait(g)`).
    Zero,
    /// Zero-arg method call on a queue-ish receiver (`q`, `queue`, `jobs`,
    /// `*_q`, …) — disambiguates `PrefetchQueue::pop` from `Vec::pop`.
    QueueRecv,
}

const BLOCKING: [(&str, Mode); 19] = [
    ("read_exact", Mode::Any),
    ("sync_all", Mode::Zero),
    ("recv", Mode::Zero),
    ("recv_timeout", Mode::Any),
    ("join", Mode::Zero),
    ("wait", Mode::Zero),
    ("pop", Mode::QueueRecv),
    ("get_or_load", Mode::Any),
    ("answer", Mode::Any),
    ("answer_plan", Mode::Any),
    ("answer_with_rows", Mode::Any),
    ("begin_plan", Mode::Any),
    ("decode_step", Mode::Any),
    ("decode_step_many", Mode::Any),
    ("prefill_chunk", Mode::Any),
    ("read_to_string", Mode::Any),
    ("read_to_end", Mode::Any),
    ("write_all", Mode::Any),
    ("flush", Mode::Zero),
];

/// `module::fn` path calls that hit the filesystem.
const FS_PATHS: [(&str, &str); 11] = [
    ("fs", "rename"),
    ("fs", "remove_file"),
    ("fs", "read"),
    ("fs", "write"),
    ("fs", "read_to_string"),
    ("fs", "create_dir_all"),
    ("fs", "read_dir"),
    ("fs", "metadata"),
    ("fs", "copy"),
    ("File", "open"),
    ("File", "create"),
];

fn queue_ish(recv: &str) -> bool {
    recv == "q"
        || recv == "queue"
        || recv == "jobs"
        || recv.ends_with("_q")
        || recv.ends_with("_queue")
        || recv.ends_with("_jobs")
}

/// Is token `i` a guard-acquiring call?  `.lock()`/`.read()`/`.write()`
/// must be zero-arg AND chased by `.unwrap()`, `.expect(…)`, or `?` (the
/// poisoning-propagation chain) so that io::Read/Write methods with the
/// same names never misfire; `lock_shard` is repo-specific and always a
/// guard.
pub(crate) fn is_guard_acquisition(toks: &[Tok], i: usize) -> bool {
    let t = &toks[i];
    if t.kind != TokKind::Ident || !GUARD_FNS.contains(&t.text.as_str()) {
        return false;
    }
    if !is_call(toks, i) || i == 0 || toks[i - 1].text != "." {
        return false;
    }
    if t.text == "lock_shard" {
        return true;
    }
    if !args_empty(toks, i + 1) {
        return false;
    }
    // token after the `)`
    let j = i + 3;
    let nxt = toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
    let nxt2 = toks.get(j + 1).map(|t| t.text.as_str()).unwrap_or("");
    nxt == "?" || (nxt == "." && (nxt2 == "unwrap" || nxt2 == "expect"))
}

/// If token `i` is a call into the blocklist, the display name of the
/// blocking call.  Also the direct-blocking seed test for the cross-file
/// may-block fixpoint (`analysis::callgraph`).
pub(crate) fn blocking_call(toks: &[Tok], i: usize) -> Option<String> {
    let t = &toks[i];
    if t.kind != TokKind::Ident {
        return None;
    }
    let name = t.text.as_str();
    // path form: `fs::rename(…)`, `File::open(…)`
    if i >= 3 && toks[i - 1].text == ":" && toks[i - 2].text == ":" {
        let seg = toks[i - 3].text.as_str();
        if FS_PATHS.iter().any(|&(s, f)| s == seg && f == name) {
            return Some(format!("{seg}::{name}"));
        }
    }
    let mode = BLOCKING.iter().find(|(n, _)| *n == name).map(|(_, m)| m)?;
    if !is_call(toks, i) {
        return None;
    }
    match mode {
        Mode::Any => {}
        Mode::Zero => {
            if !args_empty(toks, i + 1) {
                return None;
            }
        }
        Mode::QueueRecv => {
            if !is_method_call(toks, i) || !args_empty(toks, i + 1) {
                return None;
            }
            match receiver_name(toks, i - 1) {
                Some(r) if queue_ish(r) => {}
                _ => return None,
            }
        }
    }
    Some(name.to_string())
}

/// The live token range `(lo, hi)` and display name of the guard acquired
/// at token `i` — named `let` bindings to end of block (truncated by an
/// explicit `drop(bind)`), match-scrutinee temporaries through the match,
/// condition temporaries to the `{`, plain temporaries to the `;`.
/// Shared with the `lock-order` rule, which needs the same lifetimes.
pub(crate) fn guard_live_range(toks: &[Tok], i: usize) -> (usize, usize, String) {
    let n = toks.len();
    let (lo, mut hi, scope_kind) = match classify_guard_context(toks, i) {
        GuardCtx::Let(bind) => {
            let lo = stmt_end(toks, i, n) + 1;
            let hi = enclosing_block_end(toks, i, n);
            (lo, hi, format!("guard `{bind}`"))
        }
        GuardCtx::MatchScrutinee => {
            let hi = block_after(toks, i, n).map_or_else(|| stmt_end(toks, i, n), |b| b.1);
            (i + 1, hi, "match-scrutinee lock temporary".to_string())
        }
        GuardCtx::Cond => {
            let hi = block_after(toks, i, n).map_or_else(|| stmt_end(toks, i, n), |b| b.0);
            (i + 1, hi, "condition lock temporary".to_string())
        }
        GuardCtx::LetCond => {
            let hi = block_after(toks, i, n).map_or_else(|| stmt_end(toks, i, n), |b| b.1);
            (i + 1, hi, "if-let/while-let lock temporary".to_string())
        }
        GuardCtx::Temp => (i + 1, stmt_end(toks, i, n), "statement lock temporary".to_string()),
    };
    // an explicit `drop(<guard>)` ends a named guard's live scope
    if let GuardCtx::Let(bind) = classify_guard_context(toks, i) {
        if bind != "<pat>" {
            for j in lo..hi {
                if toks[j].kind == TokKind::Ident
                    && toks[j].text == "drop"
                    && toks.get(j + 1).is_some_and(|t| t.text == "(")
                    && toks.get(j + 2).is_some_and(|t| t.text == bind)
                {
                    hi = j;
                    break;
                }
            }
        }
    }
    (lo, hi.min(n), scope_kind)
}

/// Check one file.  `inter` carries the cross-file may-block results; when
/// present, calls into *transitively* blocking fns are flagged too, with
/// the full witness chain in the message.
pub fn check(
    path: &str,
    file_idx: usize,
    toks: &[Tok],
    test_regions: &[Region],
    inter: Option<(&SymbolTable, &CallGraph)>,
    diags: &mut Vec<Diag>,
) {
    let n = toks.len();
    for i in 0..n {
        if in_regions(i, test_regions) || !is_guard_acquisition(toks, i) {
            continue;
        }
        let acquired_line = toks[i].line;
        let (lo, hi, scope_kind) = guard_live_range(toks, i);
        for j in lo..hi {
            if let Some(blk) = blocking_call(toks, j) {
                diags.push(Diag {
                    file: path.to_string(),
                    line: toks[j].line,
                    rule: GUARD_ACROSS_BLOCKING,
                    message: format!(
                        "{scope_kind} (acquired line {acquired_line}) is held across \
                         blocking call `{blk}`"
                    ),
                });
            }
        }
        // transitive pass: resolved call sites into the may-block set that
        // fall inside the live range (call sites live on the enclosing fn,
        // so a nested fn's body inside the lexical range is correctly NOT
        // attributed to this guard)
        let Some((st, cg)) = inter else {
            continue;
        };
        let Some(owner_fn) = st.enclosing(file_idx, i) else {
            continue;
        };
        for site in &cg.calls[owner_fn] {
            if site.tok_idx < lo || site.tok_idx >= hi {
                continue;
            }
            // a direct seed at the same token already produced a diag
            if blocking_call(toks, site.tok_idx).is_some() {
                continue;
            }
            if cg.is_may_block(site.callee) {
                diags.push(Diag {
                    file: path.to_string(),
                    line: site.line,
                    rule: GUARD_ACROSS_BLOCKING,
                    message: format!(
                        "{scope_kind} (acquired line {acquired_line}) is held across \
                         `{}`, which may block: {}",
                        st.def(site.callee).name,
                        cg.block_chain(st, site.callee),
                    ),
                });
            }
        }
    }
}
