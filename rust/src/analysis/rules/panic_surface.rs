//! L2 `panic-surface` — `unwrap()`/`expect()`/`panic!`/`debug_assert!` are
//! forbidden in non-test code under `coordinator/`, `kvcache/`, `runtime/`
//! and `plan/`.
//!
//! The PR-2/PR-4 lesson: `debug_assert!` silently vanishes in release
//! builds, and an uncontained panic in a worker or prefetcher takes a whole
//! thread (and with it part of the pool) down.  Checked `Result` paths or a
//! contained failure (fail one request, keep the thread) are the accepted
//! replacements; `assert!` stays legal because it *is* the checked form.
//!
//! Built-in exemption: `.unwrap()`/`.expect(…)` immediately chasing a
//! zero-arg `.lock()`/`.read()`/`.write()`/`.wait(…)`/`.lock_shard(…)` call
//! propagates lock poisoning — it can only fire if another thread already
//! panicked, so it does not *originate* a panic and is allowed.

use super::super::lexer::{Tok, TokKind};
use super::super::scope::{in_regions, Region};
use super::{is_call, PANIC_SURFACE};
use crate::analysis::Diag;

const PANIC_MACROS: [&str; 7] = [
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "debug_assert",
    "debug_assert_eq",
    "debug_assert_ne",
];

/// Methods whose `Result` is a poisoning propagation, not a new panic.
const POISON_SOURCES: [&str; 5] = ["lock", "read", "write", "wait", "lock_shard"];

/// Does the receiver chain of the `.unwrap`/`.expect` at `i` end in a
/// poisoning source call?  Pattern: `… .lock() .unwrap(` — walk back over
/// the `( … )` just before the `.` and look at the method name.
fn propagates_poisoning(toks: &[Tok], i: usize) -> bool {
    if i < 2 || toks[i - 2].text != ")" {
        return false;
    }
    let mut d = 0i32;
    let mut k = i as isize - 2;
    while k >= 0 {
        let t = &toks[k as usize].text;
        if t == ")" {
            d += 1;
        } else if t == "(" {
            d -= 1;
            if d == 0 {
                break;
            }
        }
        k -= 1;
    }
    let m = k - 1;
    m >= 0
        && toks[m as usize].kind == TokKind::Ident
        && POISON_SOURCES.contains(&toks[m as usize].text.as_str())
}

pub fn check(path: &str, toks: &[Tok], test_regions: &[Region], diags: &mut Vec<Diag>) {
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || in_regions(i, test_regions) {
            continue;
        }
        let name = t.text.as_str();
        if (name == "unwrap" || name == "expect")
            && i >= 1
            && toks[i - 1].text == "."
            && is_call(toks, i)
        {
            if propagates_poisoning(toks, i) {
                continue;
            }
            diags.push(Diag {
                file: path.to_string(),
                line: t.line,
                rule: PANIC_SURFACE,
                message: format!("`.{name}()` on a non-poisoning result in lint-gated module"),
            });
        } else if PANIC_MACROS.contains(&name) && toks.get(i + 1).is_some_and(|t| t.text == "!") {
            diags.push(Diag {
                file: path.to_string(),
                line: t.line,
                rule: PANIC_SURFACE,
                message: format!("`{name}!` in lint-gated module"),
            });
        }
    }
}
