//! Artifact-free conformance tests for the chunk-lifecycle subsystem:
//! single-flight miss resolution (the duplicate-prefill counter MUST read 0
//! under contention), bit-identical spill/re-admission, and a mixed
//! get/insert/evict/spill concurrency stress with the store's accounting
//! and the resident-xor-spilled invariant checked throughout.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::time::Duration;

use anyhow::bail;
use infoflow_kv::kvcache::{ChunkKv, ChunkStore, KeyDomain, SpillTier};
use infoflow_kv::tensor::TensorF;
use infoflow_kv::util::rng::Rng;

const CHUNK_LEN: usize = 8;

/// Chunk content derived deterministically from the id, so any copy that
/// ever comes back (resident, spilled, or re-prefilled) must be
/// bit-identical to this reference.
fn det_chunk(id: u64) -> ChunkKv {
    let dims = [2usize, CHUNK_LEN, 2, 4];
    let n: usize = dims.iter().product();
    let mut rng = Rng::new(id ^ 0x00AB_CDEF);
    ChunkKv {
        id,
        tokens: (0..CHUNK_LEN as i32).map(|t| t + id as i32).collect(),
        k: TensorF::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
            .unwrap(),
        v: TensorF::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
            .unwrap(),
        key_domain: KeyDomain::Unrotated,
    }
}

fn chunk_bytes() -> usize {
    det_chunk(0).nbytes()
}

fn temp_spill_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ifkv_lifecycle_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn eight_concurrent_misses_share_one_prefill() {
    // The acceptance bar: 8 threads miss the same chunk at the same moment;
    // exactly ONE prefill runs and the duplicate-prefill counter reads 0.
    let store = Arc::new(ChunkStore::with_shards(usize::MAX, 4));
    let loader_runs = Arc::new(AtomicU64::new(0));
    let barrier = Arc::new(Barrier::new(8));
    let mut handles = Vec::new();
    for _ in 0..8 {
        let store = store.clone();
        let loader_runs = loader_runs.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            store
                .get_or_load(42, || {
                    loader_runs.fetch_add(1, Ordering::SeqCst);
                    // make the in-flight window wide enough that every
                    // follower really contends
                    std::thread::sleep(Duration::from_millis(30));
                    Ok(det_chunk(42))
                })
                .unwrap()
        }));
    }
    let reference = det_chunk(42);
    for h in handles {
        let c = h.join().unwrap();
        assert_eq!(c.id, 42);
        assert_eq!(c.k.data(), reference.k.data(), "all callers share one result");
        assert_eq!(c.v.data(), reference.v.data());
    }
    assert_eq!(loader_runs.load(Ordering::SeqCst), 1, "exactly one prefill ran");
    let life = store.lifecycle();
    assert_eq!(life.prefills.load(Ordering::Relaxed), 1);
    assert_eq!(
        life.duplicate_prefills.load(Ordering::Relaxed),
        0,
        "single-flight must prevent every duplicate prefill"
    );
    assert!(
        life.single_flight_waits.load(Ordering::Relaxed) >= 1,
        "with a 30ms in-flight window somebody must have waited"
    );
}

#[test]
fn duplicate_prefill_counter_trips_when_work_is_actually_wasted() {
    // Negative control for the tripwire: a raw insert racing a get_or_load
    // loader makes that loader's work redundant — the counter must say so.
    let store = Arc::new(ChunkStore::with_shards(usize::MAX, 1));
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let loader_store = store.clone();
    let h = std::thread::spawn(move || {
        loader_store
            .get_or_load(7, move || {
                started_tx.send(()).unwrap();
                gate_rx.recv().unwrap(); // hold the prefill open
                Ok(det_chunk(7))
            })
            .unwrap()
    });
    started_rx.recv().unwrap();
    // The chunk becomes resident behind the loader's back.
    store.insert(det_chunk(7));
    gate_tx.send(()).unwrap();
    h.join().unwrap();
    assert_eq!(
        store.lifecycle().duplicate_prefills.load(Ordering::Relaxed),
        1,
        "a prefill finishing for an already-resident chunk is a duplicate"
    );
}

#[test]
fn evicted_chunk_spills_and_readmits_bit_identical() {
    let dir = temp_spill_dir("readmit");
    let tier = Arc::new(SpillTier::new(&dir).unwrap());
    // Room for exactly one chunk: inserting B evicts (and spills) A.
    let store = ChunkStore::with_spill(chunk_bytes(), 1, tier.clone());
    let a = det_chunk(1);
    store.insert(det_chunk(1));
    store.insert(det_chunk(2));
    assert!(!store.contains(1), "A must be evicted");
    assert!(tier.contains(1), "A must be spilled, not discarded");
    assert!(store.contains(2) != tier.contains(2), "resident xor spilled");

    // Re-admission must deserialize, never re-prefill.
    let back = store
        .get_or_load(1, || bail!("spilled chunk must not be re-prefilled"))
        .unwrap();
    assert_eq!(back.tokens, a.tokens);
    assert_eq!(back.k.data(), a.k.data(), "K must round-trip bit-identically");
    assert_eq!(back.v.data(), a.v.data(), "V must round-trip bit-identically");
    assert!(
        !tier.contains(1),
        "a re-admitted chunk must not stay spilled while resident"
    );
    let life = store.lifecycle();
    assert_eq!(life.spill_admits.load(Ordering::Relaxed), 1);
    assert_eq!(life.prefills.load(Ordering::Relaxed), 0);
    assert!(life.spills.load(Ordering::Relaxed) >= 1);
    // Re-admitting A (budget 1) evicted B in turn — B must have spilled.
    assert!(tier.contains(2), "the displaced chunk must spill in turn");
    drop(back);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lifecycle_stress_mixed_ops_keeps_every_invariant() {
    const N_THREADS: u64 = 6;
    const ID_SPACE: u64 = 32;
    const OPS: u64 = 300;
    let dir = temp_spill_dir("stress");
    let tier = Arc::new(SpillTier::new(&dir).unwrap());
    // 4 shards x 2 chunks each: constant eviction/spill churn.
    let budget = 8 * chunk_bytes();
    let store = Arc::new(ChunkStore::with_spill(budget, 4, tier.clone()));
    let lookups = Arc::new(AtomicU64::new(0));
    let slack = N_THREADS as usize * chunk_bytes();
    let mut handles = Vec::new();
    for t in 0..N_THREADS {
        let store = store.clone();
        let lookups = lookups.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + t);
            for _ in 0..OPS {
                let id = rng.below(ID_SPACE as usize) as u64;
                let roll = rng.below(10);
                if roll < 5 {
                    lookups.fetch_add(1, Ordering::Relaxed);
                    let _ = store.get(id);
                } else if roll < 8 {
                    lookups.fetch_add(1, Ordering::Relaxed);
                    let c = store.get_or_load(id, || Ok(det_chunk(id))).unwrap();
                    assert_eq!(c.id, id);
                    drop(c);
                } else {
                    drop(store.insert(det_chunk(id)));
                }
                // Budget invariant after every op, modulo transient pins
                // (each live thread can hold at most one chunk Arc here).
                let bytes = store.stats().bytes;
                assert!(
                    bytes <= budget + slack,
                    "resident bytes {bytes} blew past budget {budget} + pin slack {slack}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Accounting: every counted lookup is exactly one hit or one miss.
    let stats = store.stats();
    assert_eq!(
        stats.hits + stats.misses,
        lookups.load(Ordering::Relaxed),
        "hits + misses must equal lookups"
    );

    // All pins are dropped: one settle insert per shard region brings every
    // shard back under its budget.
    for id in 0..ID_SPACE {
        drop(store.insert(det_chunk(id)));
    }
    assert!(store.stats().bytes <= budget, "store must settle under its budget");

    // Quiescent: no chunk is both resident and spilled.
    for id in 0..ID_SPACE {
        assert!(
            !(store.contains(id) && tier.contains(id)),
            "chunk {id} is resident AND spilled"
        );
    }

    // No lost chunks: every id is recoverable (resident hit, spill
    // admission, or deterministic re-prefill) and bit-identical to the
    // reference content.
    for id in 0..ID_SPACE {
        let reference = det_chunk(id);
        let c = store.get_or_load(id, || Ok(det_chunk(id))).unwrap();
        assert_eq!(c.tokens, reference.tokens, "chunk {id} tokens corrupted");
        assert_eq!(c.k.data(), reference.k.data(), "chunk {id} K corrupted");
        assert_eq!(c.v.data(), reference.v.data(), "chunk {id} V corrupted");
    }

    // The spill tier actually took part.
    let life = store.lifecycle();
    assert!(
        life.spills.load(Ordering::Relaxed) > 0,
        "stress run never exercised the spill path"
    );
    assert_eq!(
        life.spill_errors.load(Ordering::Relaxed),
        0,
        "spill IO must not fail on a healthy disk"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stats_json_exposes_lifecycle_and_tier_blocks() {
    let dir = temp_spill_dir("statsjson");
    let tier = Arc::new(SpillTier::new(&dir).unwrap());
    let store = ChunkStore::with_spill(chunk_bytes(), 1, tier);
    store.insert(det_chunk(1));
    store.insert(det_chunk(2)); // evict + spill 1
    let _ = store.get_or_load(1, || Ok(det_chunk(1))).unwrap(); // admit 1
    let j = store.stats_json();
    let life = j.get("lifecycle").unwrap();
    assert_eq!(life.get("spill_admits").unwrap().as_usize().unwrap(), 1);
    assert!(life.get("spills").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(life.get("duplicate_prefills").unwrap().as_usize().unwrap(), 0);
    let tier_stats = j.get("spill_tier").unwrap();
    assert!(tier_stats.get("writes").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(tier_stats.get("reads").unwrap().as_usize().unwrap(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}
