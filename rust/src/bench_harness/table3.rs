//! Table 3: the main LLM QA comparison — three backbones x six methods x
//! four LongBench analogs, under fixed-chunk and passage-split settings.

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::tables::{fmt4, Table};
use crate::eval::EvalRunner;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::datasets::{eval_set, ChunkingMode, Dataset};

pub fn methods(budget: usize) -> Vec<(String, MethodSpec)> {
    vec![
        ("Baseline".into(), MethodSpec::Baseline),
        ("No Recompute".into(), MethodSpec::NoRecompute),
        ("Our".into(), MethodSpec::ours(budget)),
        ("Our + Reorder".into(), MethodSpec::ours_reorder(budget)),
        ("CacheBlend".into(), MethodSpec::CacheBlend { budget }),
        ("EPIC (15%)".into(), MethodSpec::Epic { budget }),
    ]
}

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let budget = args.usize_or("budget", 16)?;
    let chunk = ctx.runtime.manifest.model.chunk;
    let backbones: Vec<String> = ["qwen-syn", "llama-syn", "glm-syn"]
        .iter()
        .filter(|b| ctx.runtime.backbone_names().iter().any(|h| h == *b))
        .map(|s| s.to_string())
        .collect();

    let mut header = vec!["Model".to_string(), "Method".to_string()];
    for mode in [ChunkingMode::FixedChunk, ChunkingMode::PassageSplit] {
        for ds in Dataset::ALL {
            header.push(format!("{}/{}", mode.name(), ds.name()));
        }
    }
    let mut table = Table::new(
        &format!("Table 3: LLM QA comparison (F1, budget {budget})"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut json_rows = vec![];
    for backbone in &backbones {
        let pipeline = ctx.pipeline(backbone)?;
        for (mname, method) in methods(budget) {
            let mut cells = vec![backbone.clone(), mname.clone()];
            let mut jrow = vec![
                ("model", Json::from(backbone.as_str())),
                ("method", Json::from(mname.as_str())),
            ];
            for mode in [ChunkingMode::FixedChunk, ChunkingMode::PassageSplit] {
                for ds in Dataset::ALL {
                    let episodes =
                        eval_set(&pipeline.vocab, chunk, ds, mode, ctx.samples, ctx.seed);
                    let store = ctx.store();
                    let out =
                        EvalRunner::new(&pipeline, &store).run(&episodes, method)?;
                    cells.push(fmt4(out.f1));
                    jrow.push((
                        Box::leak(format!("{}/{}", mode.name(), ds.name()).into_boxed_str()),
                        Json::from(out.f1),
                    ));
                }
            }
            println!("{} {} {}", backbone, mname, cells[2..].join(" "));
            table.row(cells);
            json_rows.push(Json::obj(jrow));
        }
    }
    println!("\n{}", table.render());
    ctx.dump("table3", Json::Arr(json_rows), Some(table.to_csv()))?;
    Ok(())
}
