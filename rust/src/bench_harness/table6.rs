//! Table 6: answer quality under the sequence-parallel setting — "ring
//! attention" (exact full-context attention, which is what ring attention
//! computes) vs ours (4-way chunk partition + selective recomputation),
//! F1 on three QA analogs.

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::tables::Table;
use crate::eval::EvalRunner;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::datasets::{eval_set, ChunkingMode, Dataset};

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let backbone = ctx.backbone_or_default(args);
    let pipeline = ctx.pipeline(&backbone)?;
    let budget = args.usize_or("budget", 16)?;
    let chunk = ctx.runtime.manifest.model.chunk;

    let mut table = Table::new(
        &format!("Table 6: ring attention vs ours under sequence parallelism ({backbone})"),
        &["Task", "Method", "F1 (%)"],
    );
    let mut json_rows = vec![];
    for ds in [Dataset::HotpotQa, Dataset::TwoWikiMqa, Dataset::Musique] {
        let episodes = eval_set(&pipeline.vocab, chunk, ds, ChunkingMode::FixedChunk,
                                ctx.samples, ctx.seed);
        for (name, method) in [
            ("Ring Attention", MethodSpec::Baseline),
            ("Ours", MethodSpec::ours(budget)),
        ] {
            let store = ctx.store();
            let out = EvalRunner::new(&pipeline, &store).run(&episodes, method)?;
            table.row(vec![
                ds.name().to_string(),
                name.to_string(),
                format!("{:.2}", out.f1 * 100.0),
            ]);
            json_rows.push(Json::obj(vec![
                ("task", Json::from(ds.name())),
                ("method", Json::from(name)),
                ("f1", Json::from(out.f1 * 100.0)),
            ]));
        }
    }
    println!("{}", table.render());
    ctx.dump("table6", Json::Arr(json_rows), Some(table.to_csv()))?;
    Ok(())
}
