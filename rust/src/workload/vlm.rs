//! VLM benchmark analogs (Table 4): the same decoder consuming "image
//! chunks" — serialized symbol grids — standing in for Qwen3-VL-8B on
//! OCRBench / ChartQA / RealWorldQA / HRBench4K / InfoVQA (DESIGN.md §1).
//!
//! The paper's budget knob `k` is the number of chunks the visual input is
//! split into (`k = 0` means unchunked baseline inference).

use crate::util::rng::Rng;
use crate::vocab::Vocab;

use super::lang::{Episode, EpisodeGen};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VlmBench {
    /// OCRBench-like: read one cell of a dense grid.
    OcrSyn,
    /// ChartQA-like: chart series lookup among distractor series.
    ChartSyn,
    /// RealWorldQA-like: grid lookup with heavy filler "scene" noise.
    RealWorldSyn,
    /// HRBench4K-like: high-resolution = more chunks, one tiny needle cell.
    HrBenchSyn,
    /// InfoVQA-like: mixed text facts + grid cells in one context.
    InfoVqaSyn,
}

impl VlmBench {
    pub const ALL: [VlmBench; 5] = [
        VlmBench::RealWorldSyn,
        VlmBench::ChartSyn,
        VlmBench::OcrSyn,
        VlmBench::HrBenchSyn,
        VlmBench::InfoVqaSyn,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            VlmBench::OcrSyn => "OCRBench-syn",
            VlmBench::ChartSyn => "ChartQA-syn",
            VlmBench::RealWorldSyn => "RealWorldQA-syn",
            VlmBench::HrBenchSyn => "HRBench4K-syn",
            VlmBench::InfoVqaSyn => "InfoVQA-syn",
        }
    }

    /// Sample one episode with the image split into `k.max(1)` chunks
    /// (k is the paper's chunking budget; k = 0 -> single chunk, evaluated
    /// with the Baseline method by the harness).
    pub fn sample(&self, vocab: &Vocab, chunk: usize, rng: &mut Rng, k: usize) -> Episode {
        let n_chunks = k.max(1).min(8);
        let mut g = EpisodeGen::new(vocab.clone(), chunk);
        match self {
            VlmBench::OcrSyn => {
                g.n_facts = (4, 8);
                let mut e = g.grid(rng, n_chunks);
                e.task = "ocr-syn";
                e
            }
            VlmBench::ChartSyn => {
                g.n_facts = (4, 6);
                let mut e = g.chart(rng, n_chunks);
                e.task = "chart-syn";
                e
            }
            VlmBench::RealWorldSyn => {
                g.n_facts = (2, 4);
                let mut e = g.grid(rng, n_chunks);
                e.task = "realworld-syn";
                e
            }
            VlmBench::HrBenchSyn => {
                // high resolution: double the chunk count, single needle
                let nk = (2 * n_chunks).min(8);
                g.n_facts = (2, 3);
                let mut e = g.grid(rng, nk);
                e.task = "hrbench-syn";
                e
            }
            VlmBench::InfoVqaSyn => {
                // mixed modality: half the episodes are text lookups over a
                // context that also contains grid cells, half are grid
                // lookups over a context that also contains text facts.
                let mut e = if rng.chance(0.5) {
                    g.onehop(rng, n_chunks)
                } else {
                    g.grid(rng, n_chunks)
                };
                e.task = "infovqa-syn";
                e
            }
        }
    }
}

/// A seeded eval set for one benchmark and chunking budget.
pub fn eval_set(
    vocab: &Vocab,
    chunk: usize,
    bench: VlmBench,
    k: usize,
    n: usize,
    seed: u64,
) -> Vec<Episode> {
    let mut rng = Rng::new(seed ^ ((bench as u64) << 8) ^ ((k as u64) << 20));
    (0..n).map(|_| bench.sample(vocab, chunk, &mut rng, k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benches_sample_at_all_budgets() {
        let v = Vocab::default();
        for b in VlmBench::ALL {
            for k in [0usize, 2, 4] {
                let set = eval_set(&v, 64, b, k, 3, 11);
                for e in &set {
                    assert!(!e.chunks.is_empty());
                    assert!(!e.answer.is_empty());
                    for c in &e.chunks {
                        assert_eq!(c.len(), 64);
                    }
                }
            }
        }
    }

    #[test]
    fn hrbench_has_more_chunks() {
        let v = Vocab::default();
        let hr = eval_set(&v, 64, VlmBench::HrBenchSyn, 4, 2, 1);
        let ocr = eval_set(&v, 64, VlmBench::OcrSyn, 4, 2, 1);
        assert!(hr[0].chunks.len() > ocr[0].chunks.len());
    }
}
