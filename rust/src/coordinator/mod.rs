//! The serving coordinator: a threaded request loop with dynamic batching,
//! a shared chunk store, per-session state and a metrics registry.
//!
//! (The image's offline crate mirror has no tokio, so the event loop is
//! built on std threads + channels — same architecture, first-party
//! machinery: a router thread drains the request queue into batches, worker
//! threads run the pipeline, the chunk store is shared behind a mutex.)

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod session;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::MetricsRegistry;
pub use server::{Request, Response, Server};
pub use session::SessionTable;
