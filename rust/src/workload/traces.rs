//! Request traces for the serving benchmarks: Poisson arrivals over a pool
//! of shared documents (so the chunk store sees realistic reuse), used by
//! the coordinator bench and the rag_serving example.

use crate::util::rng::Rng;
use crate::vocab::Vocab;

use super::lang::{Episode, EpisodeGen};

#[derive(Clone, Debug)]
pub struct TracedRequest {
    /// Arrival time in seconds from trace start.
    pub at_s: f64,
    pub episode: Episode,
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean request rate (req/s).
    pub rate: f64,
    pub n_requests: usize,
    /// Size of the shared document pool; smaller pool => more cache reuse.
    pub doc_pool: usize,
    pub chunks_per_request: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 4.0,
            n_requests: 32,
            doc_pool: 12,
            chunks_per_request: 4,
            seed: 0,
        }
    }
}

/// Generate a trace where requests retrieve `chunks_per_request` documents
/// from a fixed pool (multi-query RAG reuse) and ask a one-hop question
/// about a fact known to live in one of the retrieved documents.
pub fn generate(vocab: &Vocab, chunk: usize, cfg: &TraceConfig) -> Vec<TracedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let genr = EpisodeGen::new(vocab.clone(), chunk);

    // Document pool: each document is one chunk from a one-hop episode,
    // with its (key -> answer) fact recorded.
    let mut docs: Vec<(Vec<i32>, Vec<i32>, Vec<i32>)> = Vec::new(); // (chunk, prompt, answer)
    for _ in 0..cfg.doc_pool {
        let e = genr.onehop(&mut rng, 1);
        docs.push((e.chunks[0].clone(), e.prompt.clone(), e.answer.clone()));
    }

    let mut out = Vec::with_capacity(cfg.n_requests);
    let mut t = 0.0;
    for _ in 0..cfg.n_requests {
        t += rng.exponential(cfg.rate);
        // retrieve a random subset; the needle doc decides the query
        let pick = rng.choose_distinct(docs.len(), cfg.chunks_per_request.min(docs.len()));
        let needle_slot = rng.below(pick.len());
        let chunks: Vec<Vec<i32>> = pick.iter().map(|&i| docs[i].0.clone()).collect();
        let (_, prompt, answer) = &docs[pick[needle_slot]];
        out.push(TracedRequest {
            at_s: t,
            episode: Episode {
                chunks,
                prompt: prompt.clone(),
                answer: answer.clone(),
                needle_chunks: vec![needle_slot],
                task: "trace-onehop",
            },
        });
    }
    out
}

/// One turn of a session trace: like [`TracedRequest`] but tagged with the
/// session it belongs to.
#[derive(Clone, Debug)]
pub struct TracedTurn {
    /// Arrival time in seconds from trace start.
    pub at_s: f64,
    /// Session index in `0..n_sessions` — the caller maps it to a server
    /// session id.
    pub session: usize,
    pub episode: Episode,
}

/// Generate a multi-turn session trace: `n_sessions` sessions of `turns`
/// turns each.  Every turn of a session retrieves the SAME document set
/// (the session's "conversation context"), in the same order, but asks a
/// different question about it — exactly the overlap a session's cached
/// prep context and pinned chunks amortize.  Arrivals interleave across
/// sessions (Poisson per trace, round-robin turn order), so consecutive
/// submissions usually belong to DIFFERENT sessions and affinity actually
/// gets exercised.  `cfg.n_requests` is reinterpreted as `n_sessions`.
pub fn generate_sessions(
    vocab: &Vocab,
    chunk: usize,
    cfg: &TraceConfig,
    turns: usize,
) -> Vec<TracedTurn> {
    let mut rng = Rng::new(cfg.seed);
    let genr = EpisodeGen::new(vocab.clone(), chunk);
    let mut docs: Vec<(Vec<i32>, Vec<i32>, Vec<i32>)> = Vec::new(); // (chunk, prompt, answer)
    for _ in 0..cfg.doc_pool {
        let e = genr.onehop(&mut rng, 1);
        docs.push((e.chunks[0].clone(), e.prompt.clone(), e.answer.clone()));
    }

    // Each session fixes its retrieved set once.
    let n_sessions = cfg.n_requests.max(1);
    let picks: Vec<Vec<usize>> = (0..n_sessions)
        .map(|_| rng.choose_distinct(docs.len(), cfg.chunks_per_request.min(docs.len())))
        .collect();

    let mut out = Vec::with_capacity(n_sessions * turns);
    let mut t = 0.0;
    for _ in 0..turns.max(1) {
        for (session, pick) in picks.iter().enumerate() {
            t += rng.exponential(cfg.rate);
            // a different needle doc each turn: same context, new question
            let needle_slot = rng.below(pick.len());
            let chunks: Vec<Vec<i32>> = pick.iter().map(|&i| docs[i].0.clone()).collect();
            let (_, prompt, answer) = &docs[pick[needle_slot]];
            out.push(TracedTurn {
                at_s: t,
                session,
                episode: Episode {
                    chunks,
                    prompt: prompt.clone(),
                    answer: answer.clone(),
                    needle_chunks: vec![needle_slot],
                    task: "trace-session",
                },
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shape_and_reuse() {
        let v = Vocab::default();
        let cfg = TraceConfig { n_requests: 20, doc_pool: 5, ..Default::default() };
        let tr = generate(&v, 64, &cfg);
        assert_eq!(tr.len(), 20);
        // arrivals strictly increasing
        for w in tr.windows(2) {
            assert!(w[1].at_s > w[0].at_s);
        }
        // small pool => chunk reuse across requests
        let mut seen = std::collections::HashSet::new();
        for r in &tr {
            for c in &r.episode.chunks {
                seen.insert(crate::kvcache::ChunkKv::content_id(c));
            }
        }
        assert!(seen.len() <= 5, "documents must be shared across requests");
    }

    #[test]
    fn session_trace_repeats_retrieval_within_a_session() {
        let v = Vocab::default();
        let cfg = TraceConfig { n_requests: 4, doc_pool: 8, ..Default::default() };
        let tr = generate_sessions(&v, 64, &cfg, 3);
        assert_eq!(tr.len(), 12);
        for w in tr.windows(2) {
            assert!(w[1].at_s > w[0].at_s);
        }
        // every turn of a session retrieves the SAME chunk set, in order
        for sid in 0..4 {
            let turns: Vec<_> = tr.iter().filter(|r| r.session == sid).collect();
            assert_eq!(turns.len(), 3);
            for t in &turns[1..] {
                assert_eq!(t.episode.chunks, turns[0].episode.chunks);
            }
        }
        // consecutive arrivals belong to different sessions (interleaved)
        assert_ne!(tr[0].session, tr[1].session);
    }

    #[test]
    fn deterministic() {
        let v = Vocab::default();
        let cfg = TraceConfig::default();
        let a = generate(&v, 64, &cfg);
        let b = generate(&v, 64, &cfg);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].episode.chunks, b[3].episode.chunks);
    }
}
