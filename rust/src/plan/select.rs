//! Selection policies: which context rows get their KV recomputed.
//!
//! Each policy maps (optional scores, validity mask, chunk lengths) to a
//! list of buffer row indices.  The policies here are the selection rules
//! the paper sweeps — global top-k (Eq. 8), EPIC's per-chunk water-filling,
//! explicit/oracle rows, and seeded-random rows for ablation floors.

use anyhow::{anyhow, Result};

use crate::selection;
use crate::util::rng::Rng;

/// A selection rule over (scored) context rows.
pub trait SelectPolicy: Send + Sync {
    /// Registry name of this policy family (e.g. `"topk"`).
    fn name(&self) -> &'static str;
    /// Canonical grammar atom, e.g. `topk:16`.
    fn render(&self) -> String;
    /// Whether the plan must run a score stage to feed this policy.
    fn needs_scores(&self) -> bool {
        false
    }
    /// Recomputation budget, when this policy is budgeted.
    fn budget(&self) -> Option<usize> {
        None
    }
    /// Rows to recompute, in selection order.  `scores` is `Some` exactly
    /// when [`SelectPolicy::needs_scores`] is true and a score stage ran.
    fn select(
        &self,
        scores: Option<&[f32]>,
        valid: &[f32],
        chunk_lens: &[usize],
    ) -> Result<Vec<usize>>;
    /// Optional CLI-time validation against the loaded model.
    fn validate_for(&self, max_bucket: usize) -> Result<()> {
        if let Some(b) = self.budget() {
            if b > max_bucket {
                anyhow::bail!(
                    "select={}: budget {b} exceeds the largest context bucket ({max_bucket})",
                    self.render()
                );
            }
        }
        Ok(())
    }
    fn clone_box(&self) -> Box<dyn SelectPolicy>;
}

impl Clone for Box<dyn SelectPolicy> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Global top-k over the score stage's output (paper Eq. 8).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub budget: usize,
}

impl SelectPolicy for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn render(&self) -> String {
        format!("topk:{}", self.budget)
    }

    fn needs_scores(&self) -> bool {
        true
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn select(
        &self,
        scores: Option<&[f32]>,
        valid: &[f32],
        _chunk_lens: &[usize],
    ) -> Result<Vec<usize>> {
        let scores =
            scores.ok_or_else(|| anyhow!("select=topk requires a score stage"))?;
        Ok(selection::topk(scores, valid, self.budget))
    }

    fn clone_box(&self) -> Box<dyn SelectPolicy> {
        Box::new(*self)
    }
}

/// EPIC's fixed positional rule: the budget water-filled across chunk-initial
/// tokens — score-free, so plans using it carry no score stage.
#[derive(Clone, Copy, Debug)]
pub struct EpicSplit {
    pub budget: usize,
}

impl SelectPolicy for EpicSplit {
    fn name(&self) -> &'static str {
        "epic"
    }

    fn render(&self) -> String {
        format!("epic:{}", self.budget)
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn select(
        &self,
        _scores: Option<&[f32]>,
        _valid: &[f32],
        chunk_lens: &[usize],
    ) -> Result<Vec<usize>> {
        Ok(selection::epic(chunk_lens, self.budget))
    }

    fn clone_box(&self) -> Box<dyn SelectPolicy> {
        Box::new(*self)
    }
}

/// Externally supplied buffer rows (oracle ablations, `answer_with_rows`).
/// Out-of-range rows are dropped, matching the historical behaviour.
#[derive(Clone, Debug)]
pub struct Explicit {
    pub rows: Vec<usize>,
}

impl SelectPolicy for Explicit {
    fn name(&self) -> &'static str {
        "explicit"
    }

    fn render(&self) -> String {
        let rows: Vec<String> = self.rows.iter().map(|r| r.to_string()).collect();
        format!("explicit:{}", rows.join("+"))
    }

    fn select(
        &self,
        _scores: Option<&[f32]>,
        _valid: &[f32],
        chunk_lens: &[usize],
    ) -> Result<Vec<usize>> {
        let n: usize = chunk_lens.iter().sum();
        Ok(self.rows.iter().copied().filter(|&r| r < n).collect())
    }

    fn clone_box(&self) -> Box<dyn SelectPolicy> {
        Box::new(self.clone())
    }
}

/// Seeded-random selection of `budget` valid rows — the ablation floor for
/// any scored policy, deterministic per (seed, context shape).
#[derive(Clone, Copy, Debug)]
pub struct RandomSel {
    pub budget: usize,
    pub seed: u64,
}

impl SelectPolicy for RandomSel {
    fn name(&self) -> &'static str {
        "random"
    }

    fn render(&self) -> String {
        format!("random:{},seed={}", self.budget, self.seed)
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn select(
        &self,
        _scores: Option<&[f32]>,
        valid: &[f32],
        chunk_lens: &[usize],
    ) -> Result<Vec<usize>> {
        let n: usize = chunk_lens.iter().sum();
        let rows: Vec<usize> = (0..n).filter(|&i| valid[i] > 0.0).collect();
        let k = self.budget.min(rows.len());
        let mut rng = Rng::new(self.seed);
        Ok(rng
            .choose_distinct(rows.len(), k)
            .into_iter()
            .map(|i| rows[i])
            .collect())
    }

    fn clone_box(&self) -> Box<dyn SelectPolicy> {
        Box::new(*self)
    }
}
