//! Minimal JSON parser + writer (first-party: the crate builds offline).
//!
//! Covers everything the stack needs: the artifact manifest, serving config
//! files, weight sidecars and experiment-result dumps.  Strict enough for
//! round-tripping our own output; forgiving about whitespace.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors -------------------------------------------------------
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<T: Into<Json>>(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 {
            bail!("not an integer: {x}");
        }
        Ok(x as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_array(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // -- serialization ------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                for _ in 0..n {
                    o.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    // -- parsing ------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at byte {pos}");
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = s.parse().map_err(|e| anyhow!("bad number '{s}': {e}"))?;
    Ok(Json::Num(x))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("unterminated escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("bad \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Consume one UTF-8 scalar.
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow!("invalid utf-8 in string"))?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            bail!("expected object key at byte {pos}");
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        m.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 5);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("a").unwrap().usize_array().unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"ü""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\"ü");
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }
}
