//! [`GuideState`] — the per-query DFA cursor — and the masked greedy
//! argmax it applies to each decode step's logits.
//!
//! One cursor lives inside each guided `DecodeState`.  Per decode tick the
//! cost is exactly one mask lookup ([`GuideState::choose`]) plus one DFA
//! transition ([`GuideState::advance`]); the scheduler interleaves guided
//! and free-form queries with no extra bookkeeping because the cursor
//! travels with the query's own state.

use std::sync::Arc;

use crate::vocab;

use super::dfa::Guide;
use super::mask_allows;

/// Greedy argmax restricted to mask-allowed tokens, first-max-wins — the
/// same tie-breaking as `TensorF::argmax`, so a guide whose mask admits the
/// unguided winner picks the identical token.  `None` when the mask admits
/// nothing (the dead/all-masked case).
pub fn masked_argmax(logits: &[f32], mask: &[u64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &x) in logits.iter().enumerate() {
        if !mask_allows(mask, i as i32) {
            continue;
        }
        best = match best {
            Some(b) if logits[b] >= x => Some(b),
            _ => Some(i),
        };
    }
    best
}

/// A query's position in its guide: the current DFA state plus a sticky
/// rejection flag.  Rejection — an emitted token with no edge, or a state
/// admitting nothing — is terminal and never panics: the decode loop ends
/// the answer and the coordinator counts it under `guide_rejections`.
#[derive(Clone, Debug)]
pub struct GuideState {
    guide: Arc<Guide>,
    at: u32,
    rejected: bool,
}

impl GuideState {
    /// A fresh cursor at the guide's start state.
    pub fn new(guide: Arc<Guide>) -> GuideState {
        GuideState {
            guide,
            at: 0,
            rejected: false,
        }
    }

    pub fn guide(&self) -> &Arc<Guide> {
        &self.guide
    }

    /// The current state's allowed-token mask (empty once rejected).
    pub fn mask(&self) -> &[u64] {
        if self.rejected {
            &[]
        } else {
            self.guide.mask_of(self.at)
        }
    }

    pub fn is_rejected(&self) -> bool {
        self.rejected
    }

    /// Is the answer walked so far a complete match?  EOS may only be
    /// chosen here, and retiring here satisfies the guide.
    pub fn is_accepting(&self) -> bool {
        !self.rejected && self.guide.is_accepting(self.at)
    }

    /// Advance one DFA transition for an emitted token.  EOS is a
    /// terminator, not a symbol: it never moves the cursor (and in an
    /// accepting state it is exactly where the answer should stop).
    pub fn advance(&mut self, tok: i32) {
        if self.rejected || tok == vocab::EOS {
            return;
        }
        match self.guide.next_of(self.at, tok) {
            Some(s) => self.at = s,
            None => self.rejected = true,
        }
    }

    /// Masked greedy choice of the next token.  `None` marks this cursor
    /// rejected (dead/all-masked state): the caller terminates the answer.
    pub fn choose(&mut self, logits: &[f32]) -> Option<i32> {
        if self.rejected {
            return None;
        }
        match masked_argmax(logits, self.guide.mask_of(self.at)) {
            Some(t) => Some(t as i32),
            None => {
                self.rejected = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vocab::{Vocab, EOS};

    fn v() -> Vocab {
        Vocab::default()
    }

    #[test]
    fn masked_argmax_is_first_max_wins_under_the_mask() {
        // Tokens 0..4; mask admits 1 and 3 only.
        let mask = [0b1010u64];
        let logits = [9.0, 1.0, 9.0, 1.0];
        assert_eq!(masked_argmax(&logits, &mask), Some(1), "ties break to the first");
        let logits2 = [9.0, 1.0, 9.0, 2.0];
        assert_eq!(masked_argmax(&logits2, &mask), Some(3));
        assert_eq!(masked_argmax(&logits, &[0u64]), None, "empty mask");
        assert_eq!(masked_argmax(&[], &mask), None, "no logits");
    }

    #[test]
    fn masked_argmax_agrees_with_unmasked_when_winner_is_allowed() {
        let logits: Vec<f32> = (0..144).map(|i| ((i * 37) % 91) as f32).collect();
        let all = [u64::MAX, u64::MAX, u64::MAX];
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        assert_eq!(masked_argmax(&logits, &all), Some(best));
    }

    #[test]
    fn cursor_walks_accepts_and_terminates() {
        let vb = v();
        let g = Arc::new(Guide::compile("key.val.val", &vb).unwrap());
        let mut s = GuideState::new(g);
        assert!(!s.is_accepting());
        // Start state admits keys only.
        let uniform = vec![1.0f32; vb.vocab];
        let first = s.choose(&uniform).unwrap();
        assert!(vb.is_key(first));
        s.advance(first);
        s.advance(vb.val_base);
        s.advance(vb.val_base + 1);
        assert!(s.is_accepting());
        // In the accepting state the mask admits EOS.
        assert!(mask_allows(s.mask(), EOS));
        // EOS never moves the cursor.
        s.advance(EOS);
        assert!(s.is_accepting());
    }

    #[test]
    fn wrong_token_rejects_sticky_and_silent() {
        let vb = v();
        let g = Arc::new(Guide::compile("val.val", &vb).unwrap());
        let mut s = GuideState::new(g);
        s.advance(vb.key_base); // not a val: no edge
        assert!(s.is_rejected());
        assert!(!s.is_accepting());
        assert!(s.mask().is_empty());
        assert_eq!(s.choose(&vec![1.0f32; vb.vocab]), None);
        // Still rejected after more advances.
        s.advance(vb.val_base);
        assert!(s.is_rejected());
    }

    #[test]
    fn dead_state_choose_returns_none_once() {
        let vb = v();
        // Hand-built guide: state 0 admits v0 with an edge to state 1;
        // state 1 is non-accepting with an empty mask and no edges — a
        // genuine dead state unreachable through Thompson construction.
        let w = vb.mask_words();
        let mut masks = vec![0u64; 2 * w];
        let v0 = vb.val_base as usize;
        masks[v0 / 64] |= 1u64 << (v0 % 64);
        let mut next = vec![super::super::DEAD; 2 * vb.vocab];
        next[v0] = 1;
        let g = Arc::new(Guide::from_raw(
            "crafted".into(),
            vb.vocab as u32,
            w as u32,
            vec![false, false],
            masks,
            next,
        ));
        let mut s = GuideState::new(g);
        let uniform = vec![1.0f32; vb.vocab];
        assert_eq!(s.choose(&uniform), Some(vb.val_base));
        s.advance(vb.val_base);
        assert!(!s.is_rejected());
        assert_eq!(s.choose(&uniform), None, "all-masked state ends the answer");
        assert!(s.is_rejected());
    }
}
