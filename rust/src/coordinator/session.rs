//! Session table: multi-query sessions pin their retrieved documents so the
//! chunk store keeps them resident between queries (the paper's interactive
//! / multi-query amortization setting).

use std::collections::HashMap;
use std::sync::Arc;

use crate::kvcache::{ChunkId, ChunkKv};

#[derive(Default)]
pub struct Session {
    /// Pinned chunks (Arc keeps them out of LRU eviction).
    pinned: HashMap<ChunkId, Arc<ChunkKv>>,
    pub queries_served: u64,
}

impl Session {
    pub fn pin(&mut self, chunk: Arc<ChunkKv>) {
        self.pinned.insert(chunk.id, chunk);
    }

    pub fn pinned_ids(&self) -> Vec<ChunkId> {
        self.pinned.keys().copied().collect()
    }

    pub fn pinned_bytes(&self) -> usize {
        self.pinned.values().map(|c| c.nbytes()).sum()
    }
}

/// Registry of live sessions.
#[derive(Default)]
pub struct SessionTable {
    sessions: HashMap<u64, Session>,
    next_id: u64,
}

impl SessionTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn open(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.insert(id, Session::default());
        id
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    pub fn close(&mut self, id: u64) -> bool {
        self.sessions.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF;

    fn chunk(id: u64) -> Arc<ChunkKv> {
        Arc::new(ChunkKv {
            id,
            tokens: vec![1, 2],
            k: TensorF::zeros(&[1, 2, 1, 2]),
            v: TensorF::zeros(&[1, 2, 1, 2]),
        })
    }

    #[test]
    fn lifecycle() {
        let mut t = SessionTable::new();
        let a = t.open();
        let b = t.open();
        assert_ne!(a, b);
        assert_eq!(t.len(), 2);
        t.get_mut(a).unwrap().pin(chunk(5));
        t.get_mut(a).unwrap().queries_served += 1;
        assert_eq!(t.get_mut(a).unwrap().pinned_ids(), vec![5]);
        assert!(t.close(a));
        assert!(!t.close(a));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn pinning_keeps_arc_alive() {
        let mut t = SessionTable::new();
        let s = t.open();
        let c = chunk(9);
        let weak = Arc::downgrade(&c);
        t.get_mut(s).unwrap().pin(c);
        assert!(weak.upgrade().is_some());
        t.close(s);
        assert!(weak.upgrade().is_none(), "closing releases pins");
    }
}
