//! Chunk-level KV cache management: the store (offline prefilled chunks,
//! LRU + byte budget + disk persistence) and the per-query assembly/layout
//! machinery (padded context buffers, row patching, the decode buffer).

pub mod layout;
pub mod store;

pub use layout::{AssembledContext, DecodeBuffer};
pub use store::{ChunkId, ChunkKv, ChunkStore, StoreStats};
