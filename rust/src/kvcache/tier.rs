//! The disk spill tier: one file per evicted chunk, in the same serialized
//! record format as [`super::store`]'s persistence (so a spilled file and a
//! saved store are mutually intelligible), with an in-memory index of what
//! is on disk — now under a configurable **byte budget** with LRU file
//! eviction, so the disk tier can no longer grow without bound.
//!
//! The tier itself is deliberately dumb storage — `spill` / `take` /
//! `discard` plus an index.  All ordering guarantees (who may write or
//! consume a given id, never holding a chunk resident and spilled at once)
//! are enforced by the [`super::store::ChunkStore`] lifecycle machinery,
//! which serializes every per-id tier operation under that id's
//! single-flight slot.  Tier-internal budget eviction needs no such slot:
//! a spill publishes its file (rename), indexes it, picks victims AND
//! unlinks them all under one index-lock critical section, so an eviction
//! can never delete a file that a concurrent `spill` of the same id just
//! re-published — and a concurrent `take` either got the chunk first or
//! misses cleanly and falls back to a re-prefill.
//!
//! Round-trips are bit-identical: tokens and both KV tensors are serialized
//! as little-endian words, so a re-admitted chunk is exactly the chunk that
//! was evicted.  Spill files survive restarts: [`SpillTier::new`] re-indexes
//! whatever `<id:016x>.kv` files a previous process left in the directory
//! (and a smaller budget on reopen trims the oldest files down to fit).

use std::collections::HashMap;
use std::fs;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::kvcache::store::{
    read_chunk_record, write_chunk_record, ChunkId, ChunkKv, STORE_MAGIC, STORE_MAGIC_V1,
};
use crate::util::json::Json;

/// Per-file index entry: serialized size + recency tick (larger = newer).
struct FileMeta {
    size: u64,
    tick: u64,
}

/// The in-memory truth of what is on disk, plus the running byte total.
#[derive(Default)]
struct TierIndex {
    files: HashMap<ChunkId, FileMeta>,
    bytes: u64,
    tick: u64,
}

impl TierIndex {
    fn insert(&mut self, id: ChunkId, size: u64) {
        self.tick += 1;
        if let Some(old) = self.files.insert(id, FileMeta { size, tick: self.tick }) {
            self.bytes -= old.size;
        }
        self.bytes += size;
    }

    fn remove(&mut self, id: ChunkId) -> Option<u64> {
        let meta = self.files.remove(&id)?;
        self.bytes -= meta.size;
        Some(meta.size)
    }

    /// Oldest-first victims until the index fits `budget`.  Entries leave
    /// the index here (under the caller's lock); the caller unlinks the
    /// files afterwards.
    fn evict_to(&mut self, budget: u64) -> Vec<ChunkId> {
        let mut victims = Vec::new();
        while self.bytes > budget {
            let Some(oldest) =
                self.files.iter().min_by_key(|(_, m)| m.tick).map(|(id, _)| *id)
            else {
                break;
            };
            self.remove(oldest);
            victims.push(oldest);
        }
        victims
    }
}

pub struct SpillTier {
    dir: PathBuf,
    /// Disk byte budget; `u64::MAX` means unbounded (the historical
    /// behaviour of [`SpillTier::new`]).
    budget_bytes: u64,
    index: Mutex<TierIndex>,
    writes: AtomicU64,
    reads: AtomicU64,
    discards: AtomicU64,
    /// Files deleted by budget eviction (disk pressure, not consumption).
    evictions: AtomicU64,
}

impl SpillTier {
    /// Open (creating if needed) an **unbounded** spill directory,
    /// re-indexing any chunk files a previous process left behind.
    pub fn new(dir: impl Into<PathBuf>) -> Result<SpillTier> {
        SpillTier::with_budget(dir, u64::MAX)
    }

    /// Open a spill directory bounded to `budget_bytes` of serialized chunk
    /// files.  Exceeding the budget evicts the least-recently-written files
    /// (a spilled chunk's recency renews every time it is re-spilled).  If
    /// the directory already holds more than the budget, the oldest files
    /// (by modification time, the best cross-restart recency signal) are
    /// trimmed immediately.
    pub fn with_budget(dir: impl Into<PathBuf>, budget_bytes: u64) -> Result<SpillTier> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .map_err(|e| anyhow!("creating spill dir {}: {e}", dir.display()))?;
        // Re-index in mtime order so ticks reflect write recency across the
        // restart, not filesystem iteration order.
        let mut found: Vec<(std::time::SystemTime, ChunkId, u64)> = Vec::new();
        let entries = fs::read_dir(&dir)
            .map_err(|e| anyhow!("reading spill dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(hex) = name.strip_suffix(".kv") else { continue };
            let Ok(id) = ChunkId::from_str_radix(hex, 16) else { continue };
            let meta = entry.metadata()?;
            let mtime = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
            found.push((mtime, id, meta.len()));
        }
        found.sort_by_key(|(mtime, id, _)| (*mtime, *id));
        let mut index = TierIndex::default();
        for &(_, id, size) in &found {
            index.insert(id, size);
        }
        // Startup trim: `found` is already oldest-first, so walk it instead
        // of re-scanning the map per victim (reopening a huge unbounded dir
        // with a small budget would otherwise be quadratic).
        let tier = SpillTier {
            dir,
            budget_bytes,
            index: Mutex::new(index),
            writes: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            discards: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        };
        {
            let mut index = tier.index.lock().unwrap();
            let mut oldest = found.iter();
            while index.bytes > budget_bytes {
                let Some(&(_, id, _)) = oldest.next() else { break };
                if index.remove(id).is_some() {
                    // lint:allow(guard-across-blocking, reason="startup trim: unlink must stay inside the index critical section (PR-4 re-spill race class)")
                    let _ = fs::remove_file(tier.path(id));
                    tier.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        Ok(tier)
    }

    fn path(&self, id: ChunkId) -> PathBuf {
        self.dir.join(format!("{id:016x}.kv"))
    }

    pub fn contains(&self, id: ChunkId) -> bool {
        self.index.lock().unwrap().files.contains_key(&id)
    }

    /// Number of chunks currently spilled.
    pub fn len(&self) -> usize {
        self.index.lock().unwrap().files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total serialized bytes currently on disk.
    pub fn bytes(&self) -> u64 {
        self.index.lock().unwrap().bytes
    }

    /// The configured disk budget (`u64::MAX` = unbounded).
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Files deleted so far by budget eviction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Ids currently spilled (for invariant checks in tests).
    pub fn ids(&self) -> Vec<ChunkId> {
        self.index.lock().unwrap().files.keys().copied().collect()
    }

    /// Serialize `chunk` to its per-chunk file.  Write-then-rename, so a
    /// crash mid-write never leaves a half-record behind the index.  If the
    /// write pushes the tier over its byte budget, the least-recently-
    /// written files are evicted (possibly including this one, when a
    /// single chunk exceeds the whole budget).
    pub fn spill(&self, chunk: &ChunkKv) -> Result<()> {
        let final_path = self.path(chunk.id);
        let tmp = final_path.with_extension("tmp");
        {
            let f = fs::File::create(&tmp)
                .map_err(|e| anyhow!("creating {}: {e}", tmp.display()))?;
            let mut w = BufWriter::new(f);
            w.write_all(STORE_MAGIC)?;
            write_chunk_record(&mut w, chunk)?;
            w.flush()?;
        }
        let size = fs::metadata(&tmp)?.len();
        // Publish (rename), index, and evict under ONE critical section:
        // eviction picks victims and unlinks their files while holding the
        // lock, so it can never race a concurrent re-spill of a victim id
        // into deleting the freshly published file.  The heavy serialization
        // above stays outside the lock; only rename/unlink sit inside.
        {
            let mut index = self.index.lock().unwrap();
            // lint:allow(guard-across-blocking, reason="publish rename must sit inside the index critical section; splitting it reintroduces the PR-4 re-spill race")
            fs::rename(&tmp, &final_path)
                .map_err(|e| anyhow!("renaming into {}: {e}", final_path.display()))?;
            index.insert(chunk.id, size);
            for id in index.evict_to(self.budget_bytes) {
                // lint:allow(guard-across-blocking, reason="victim unlink must sit inside the same critical section as the rename (PR-4 re-spill race)")
                let _ = fs::remove_file(self.path(id));
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Remove and deserialize a spilled chunk ([`None`] if `id` is not
    /// spilled).  The index entry and the file are both gone before this
    /// returns — corrupt files included, so a bad record cannot wedge its
    /// id (the caller just falls back to a re-prefill).
    // lint:requires(flight)
    pub fn take(&self, id: ChunkId) -> Result<Option<ChunkKv>> {
        if self.index.lock().unwrap().remove(id).is_none() {
            return Ok(None);
        }
        let path = self.path(id);
        let out = read_spill_file(&path, id);
        let _ = fs::remove_file(&path);
        self.reads.fetch_add(1, Ordering::Relaxed);
        out.map(Some)
    }

    /// Drop a spilled chunk without reading it; `true` if one was indexed.
    // lint:requires(flight)
    pub fn discard(&self, id: ChunkId) -> bool {
        if self.index.lock().unwrap().remove(id).is_none() {
            return false;
        }
        let _ = fs::remove_file(self.path(id));
        self.discards.fetch_add(1, Ordering::Relaxed);
        true
    }

    pub fn stats_json(&self) -> Json {
        let budget = if self.budget_bytes == u64::MAX {
            Json::Null
        } else {
            Json::from(self.budget_bytes as f64)
        };
        Json::obj(vec![
            ("chunks", Json::from(self.len())),
            ("bytes", Json::from(self.bytes() as f64)),
            ("budget_bytes", budget),
            ("writes", Json::from(self.writes.load(Ordering::Relaxed) as f64)),
            ("reads", Json::from(self.reads.load(Ordering::Relaxed) as f64)),
            ("discards", Json::from(self.discards.load(Ordering::Relaxed) as f64)),
            ("evictions", Json::from(self.evictions.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Parse one spill file: magic + exactly one chunk record for `id`.
fn read_spill_file(path: &std::path::Path, id: ChunkId) -> Result<ChunkKv> {
    let f = fs::File::open(path)
        .map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
    let total = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .map_err(|e| anyhow!("{}: reading magic: {e}", path.display()))?;
    let v2 = if &magic == STORE_MAGIC {
        true
    } else if &magic == STORE_MAGIC_V1 {
        // Legacy pre-domain-flag spill file left by an older process.  The
        // tier stays dumb: it surfaces the record's domain as read
        // (`RotatedLocal`) and lets the store's admission path migrate it.
        false
    } else {
        bail!("{}: bad magic", path.display());
    };
    let mut remaining = total.saturating_sub(8);
    let chunk = read_chunk_record(&mut r, &mut remaining, v2)
        .map_err(|e| anyhow!("{}: {e:#}", path.display()))?
        .ok_or_else(|| anyhow!("{}: empty spill file", path.display()))?;
    if chunk.id != id {
        bail!(
            "{}: holds chunk {:#018x}, expected {id:#018x}",
            path.display(),
            chunk.id
        );
    }
    Ok(chunk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ifkv_tier_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn temp_tier(tag: &str) -> SpillTier {
        SpillTier::new(temp_dir(tag)).unwrap()
    }

    fn rand_chunk(rng: &mut Rng, id: ChunkId, c: usize) -> ChunkKv {
        let dims = [2usize, c, 2, 4];
        let n: usize = dims.iter().product();
        ChunkKv {
            id,
            tokens: (0..c as i32).map(|t| t + rng.below(7) as i32).collect(),
            k: TensorF::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
                .unwrap(),
            v: TensorF::from_vec(&dims, (0..n).map(|_| rng.normal() as f32).collect())
                .unwrap(),
            key_domain: crate::kvcache::store::KeyDomain::Unrotated,
        }
    }

    #[test]
    fn spill_take_roundtrip_is_bit_identical() {
        let tier = temp_tier("roundtrip");
        let mut rng = Rng::new(41);
        let chunk = rand_chunk(&mut rng, 0xDEAD_BEEF, 8);
        tier.spill(&chunk).unwrap();
        assert!(tier.contains(chunk.id));
        assert_eq!(tier.len(), 1);
        assert!(tier.bytes() > 0);
        let back = tier.take(chunk.id).unwrap().expect("chunk was spilled");
        assert_eq!(back.id, chunk.id);
        assert_eq!(back.tokens, chunk.tokens);
        assert_eq!(back.key_domain, chunk.key_domain, "domain flag must survive the tier");
        // bit-identical, not approximately equal
        assert_eq!(back.k.shape(), chunk.k.shape());
        assert_eq!(back.k.data(), chunk.k.data());
        assert_eq!(back.v.data(), chunk.v.data());
        // consumed: neither indexed nor on disk
        assert!(!tier.contains(chunk.id));
        assert!(tier.take(chunk.id).unwrap().is_none());
        assert!(tier.is_empty());
        assert_eq!(tier.bytes(), 0, "byte accounting must drain with the index");
    }

    #[test]
    fn reopen_reindexes_existing_files() {
        let dir = temp_dir("reopen");
        let mut rng = Rng::new(42);
        let chunk = rand_chunk(&mut rng, 77, 8);
        {
            let tier = SpillTier::new(&dir).unwrap();
            tier.spill(&chunk).unwrap();
        }
        let tier = SpillTier::new(&dir).unwrap();
        assert!(tier.contains(77), "restart must re-index spilled chunks");
        let back = tier.take(77).unwrap().unwrap();
        assert_eq!(back.k.data(), chunk.k.data());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_file_errors_and_unwedges_the_id() {
        let tier = temp_tier("corrupt");
        let mut rng = Rng::new(43);
        let chunk = rand_chunk(&mut rng, 99, 8);
        tier.spill(&chunk).unwrap();
        // truncate the file behind the index's back
        let path = tier.path(99);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(tier.take(99).is_err(), "corrupt spill file must error");
        // ...but the id is consumed, so the caller can re-prefill freely
        assert!(!tier.contains(99));
        assert!(tier.take(99).unwrap().is_none());
    }

    #[test]
    fn discard_removes_file_and_index() {
        let tier = temp_tier("discard");
        let mut rng = Rng::new(44);
        tier.spill(&rand_chunk(&mut rng, 5, 8)).unwrap();
        assert!(tier.discard(5));
        assert!(!tier.discard(5), "second discard is a no-op");
        assert!(!tier.path(5).exists());
        assert!(tier.is_empty());
    }

    #[test]
    fn budget_evicts_oldest_files_first() {
        let dir = temp_dir("budget");
        let mut rng = Rng::new(45);
        // Learn one file's size, then budget for exactly 3 of them.
        let probe = SpillTier::new(&dir).unwrap();
        probe.spill(&rand_chunk(&mut rng, 0, 8)).unwrap();
        let one = probe.bytes();
        assert!(probe.discard(0));
        drop(probe);

        let tier = SpillTier::with_budget(&dir, 3 * one).unwrap();
        for id in 1..=3u64 {
            tier.spill(&rand_chunk(&mut rng, id, 8)).unwrap();
        }
        assert_eq!(tier.len(), 3);
        assert_eq!(tier.evictions(), 0);
        // A 4th spill must evict the oldest (id 1), and only it.
        tier.spill(&rand_chunk(&mut rng, 4, 8)).unwrap();
        assert_eq!(tier.len(), 3);
        assert!(!tier.contains(1), "oldest file must be evicted");
        assert!(!tier.path(1).exists(), "evicted file must leave the disk");
        for id in 2..=4u64 {
            assert!(tier.contains(id), "newer file {id} must survive");
        }
        assert_eq!(tier.evictions(), 1);
        assert!(tier.bytes() <= 3 * one, "bytes must stay under the budget");
        // Re-spilling an existing id renews its recency: 2 is now newest,
        // so the next eviction takes 3.
        tier.spill(&rand_chunk(&mut rng, 2, 8)).unwrap();
        tier.spill(&rand_chunk(&mut rng, 5, 8)).unwrap();
        assert!(tier.contains(2), "re-spilled id must be most-recent");
        assert!(!tier.contains(3));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn budget_smaller_than_one_chunk_keeps_nothing_but_never_errors() {
        let dir = temp_dir("tiny_budget");
        let mut rng = Rng::new(46);
        let tier = SpillTier::with_budget(&dir, 8).unwrap();
        tier.spill(&rand_chunk(&mut rng, 1, 8)).unwrap();
        assert!(tier.is_empty(), "a chunk larger than the whole budget is dropped");
        assert_eq!(tier.evictions(), 1);
        assert!(tier.take(1).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_with_smaller_budget_trims_to_fit() {
        let dir = temp_dir("reopen_trim");
        let mut rng = Rng::new(47);
        let one = {
            let tier = SpillTier::new(&dir).unwrap();
            for id in 1..=4u64 {
                tier.spill(&rand_chunk(&mut rng, id, 8)).unwrap();
            }
            tier.bytes() / 4
        };
        let tier = SpillTier::with_budget(&dir, 2 * one).unwrap();
        assert_eq!(tier.len(), 2, "reopen must trim down to the new budget");
        assert!(tier.bytes() <= 2 * one);
        assert_eq!(tier.evictions(), 2);
        // whatever survived is still readable
        for id in tier.ids() {
            assert!(tier.take(id).unwrap().is_some());
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_json_reports_disk_pressure() {
        let dir = temp_dir("stats");
        let mut rng = Rng::new(48);
        let tier = SpillTier::with_budget(&dir, 1 << 20).unwrap();
        tier.spill(&rand_chunk(&mut rng, 9, 8)).unwrap();
        let j = tier.stats_json();
        assert_eq!(j.get("chunks").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("budget_bytes").unwrap().as_usize().unwrap(),
            1 << 20
        );
        assert_eq!(j.get("evictions").unwrap().as_usize().unwrap(), 0);
        // unbounded tiers report a null budget
        let unbounded = temp_tier("stats_unbounded");
        assert_eq!(*unbounded.stats_json().get("budget_bytes").unwrap(), Json::Null);
        let _ = fs::remove_dir_all(&dir);
    }
}
