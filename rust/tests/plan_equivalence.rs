//! Plan-lowering equivalence + plan-grammar conformance, on the stub
//! runtime (always executed, no artifacts needed).
//!
//! Three guarantees, layered:
//!
//! 1. **Lowering equivalence** — every legacy `MethodSpec` lowers to a
//!    `QueryPlan` whose `QueryResult` is bit-identical to the facade path,
//!    *through the grammar*: the plan is rendered to its canonical string,
//!    re-parsed, JSON round-tripped, and still answers identically.
//! 2. **Grammar round-trip** — `parse ∘ render == id` over a randomized
//!    space of valid plans (property test).
//! 3. **Hybrid plans** — stage recombinations the old enum could not
//!    express (deviation-scored reorder, positional-scored top-k) run end
//!    to end, are pinned by the `tests/golden/plans.snap` snapshot, and
//!    flow through the full serving stack with per-stage timings visible
//!    in `metrics_json`.
//!
//! Golden file: `tests/golden/plans.snap` — bootstraps on first run (after
//! proving run-to-run determinism); commit it to lock plan behaviour
//! across PRs.  `UPDATE_GOLDEN=1` rewrites it intentionally.
//!
//! Every grid row prints a `plan-grid: <name> -> <grammar>` line so the CI
//! job summary can list the plans the conformance grid exercised.

use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Arc;

use infoflow_kv::config::MethodSpec;
use infoflow_kv::kvcache::ChunkStore;
use infoflow_kv::pipeline::{Pipeline, QueryResult};
use infoflow_kv::plan::QueryPlan;
use infoflow_kv::runtime::exec::ModelSession;
use infoflow_kv::runtime::Runtime;
use infoflow_kv::util::rng::Rng;
use infoflow_kv::workload::{Episode, EpisodeGen};

const STUB_SEED: u64 = 2603;
const BUDGET: usize = 8;

fn stub_pipeline() -> (Arc<Runtime>, Pipeline) {
    let rt = Arc::new(Runtime::stub(STUB_SEED));
    let p = Pipeline::new(ModelSession::new(rt.clone(), "stub").unwrap()).unwrap();
    (rt, p)
}

fn episodes(p: &Pipeline, rt: &Runtime) -> Vec<Episode> {
    let genr = EpisodeGen::new(p.vocab.clone(), rt.manifest.model.chunk);
    [(101u64, 4usize), (102, 3)]
        .iter()
        .map(|(seed, n_chunks)| {
            let mut rng = Rng::new(*seed);
            genr.onehop(&mut rng, *n_chunks)
        })
        .collect()
}

fn answer_plan(p: &Pipeline, e: &Episode, plan: &QueryPlan) -> QueryResult {
    let store = ChunkStore::new(1 << 30);
    let (chunks, _) = p.prepare_chunks(&store, &e.chunks).unwrap();
    p.answer_plan(&chunks, &e.prompt, plan).unwrap()
}

fn assert_same_result(a: &QueryResult, b: &QueryResult, what: &str) {
    assert_eq!(a.answer, b.answer, "{what}: answer drifted");
    assert_eq!(a.selected, b.selected, "{what}: selection drifted");
    assert_eq!(
        a.selected_positions, b.selected_positions,
        "{what}: selected positions drifted"
    );
    assert_eq!(a.chunk_order, b.chunk_order, "{what}: chunk order drifted");
}

fn all_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::Baseline,
        MethodSpec::NoRecompute,
        MethodSpec::ours(BUDGET),
        MethodSpec::ours_reorder(BUDGET),
        MethodSpec::CacheBlend { budget: BUDGET },
        MethodSpec::Epic { budget: BUDGET },
    ]
}

/// Hybrid plans: stage recombinations the closed enum could not express.
fn hybrid_plans() -> Vec<(&'static str, &'static str)> {
    vec![
        // §4.3 reorder driven by CacheBlend's deviation signal, then the
        // paper's norm-scored top-k selection.
        (
            "dev-reorder",
            "reorder=deviation;score=norm:layer2,geom=global;select=topk:8",
        ),
        // EPIC's positional prior as a score feeding global top-k.
        ("positional-topk", "score=positional;select=topk:8"),
        // Norm-scored reorder composed with EPIC's split selection.
        ("reorder-epic", "reorder=norm:layer2,geom=hltp;select=epic:8"),
        // Seeded-random selection floor.
        ("random-floor", "select=random:8,seed=13"),
    ]
}

#[test]
fn every_method_lowers_to_an_equivalent_plan() {
    let (rt, p) = stub_pipeline();
    for e in &episodes(&p, &rt) {
        for m in all_methods() {
            let facade = {
                let store = ChunkStore::new(1 << 30);
                let (chunks, _) = p.prepare_chunks(&store, &e.chunks).unwrap();
                p.answer(&chunks, &e.prompt, m).unwrap()
            };
            let plan = m.to_plan();
            // Through the grammar: render → parse must preserve behaviour.
            let reparsed = QueryPlan::parse(&plan.render()).unwrap();
            assert_same_result(
                &facade,
                &answer_plan(&p, e, &reparsed),
                &format!("{} via grammar", plan.render()),
            );
            // And through the JSON form.
            let rejson = QueryPlan::from_json(&plan.to_json()).unwrap();
            assert_same_result(
                &facade,
                &answer_plan(&p, e, &rejson),
                &format!("{} via JSON", plan.render()),
            );
        }
    }
}

#[test]
fn answer_with_rows_is_the_explicit_select_policy() {
    let (rt, p) = stub_pipeline();
    let e = &episodes(&p, &rt)[0];
    let rows = vec![3usize, 9, 12, 700]; // 700 is out of range -> dropped
    let store = ChunkStore::new(1 << 30);
    let (chunks, _) = p.prepare_chunks(&store, &e.chunks).unwrap();
    let facade = p.answer_with_rows(&chunks, &e.prompt, rows.clone()).unwrap();
    let plan = QueryPlan::parse("select=explicit:3+9+12+700").unwrap();
    let via_plan = p.answer_plan(&chunks, &e.prompt, &plan).unwrap();
    assert_same_result(&facade, &via_plan, "explicit rows");
    assert_eq!(facade.selected, vec![3, 9, 12], "out-of-range row must drop");
}

#[test]
fn grammar_roundtrip_property() {
    // parse ∘ render == id over a randomized space of valid plans.
    let mut rng = Rng::new(0xB1A5);
    let geoms = ["global", "hlhp", "hltp", "tltp"];
    for _ in 0..200 {
        let mut clauses: Vec<String> = Vec::new();
        if rng.chance(0.5) {
            let atom = match rng.below(3) {
                0 => format!(
                    "norm:layer{},geom={}",
                    rng.below(4),
                    geoms[rng.below(4)]
                ),
                1 => "deviation".to_string(),
                _ => "positional".to_string(),
            };
            clauses.push(format!("reorder={atom}"));
        }
        // select (+ score when the select consumes one)
        match rng.below(4) {
            0 => {
                let score = match rng.below(3) {
                    0 => format!(
                        "norm:layer{},geom={}",
                        rng.below(4),
                        geoms[rng.below(4)]
                    ),
                    1 => "deviation".to_string(),
                    _ => "positional".to_string(),
                };
                clauses.push(format!("score={score}"));
                clauses.push(format!("select=topk:{}", 1 + rng.below(64)));
            }
            1 => clauses.push(format!("select=epic:{}", 1 + rng.below(64))),
            2 => clauses.push(format!(
                "select=random:{},seed={}",
                1 + rng.below(64),
                rng.below(1000)
            )),
            _ => {
                let rows: Vec<String> =
                    (0..rng.below(6)).map(|_| rng.below(512).to_string()).collect();
                clauses.push(format!("select=explicit:{}", rows.join("+")));
            }
        }
        // Optionally a guided-decode stage: any chunked plan composes with
        // decode=, and the canonical render keeps the pattern verbatim.
        let decodes = [
            "decode=json",
            "decode=regex:val.val",
            "decode=regex:key.(val|filler)*",
            "decode=regex:v3|k0.any?",
            "decode=regex:(key|val)*",
            "decode=regex:f0.f1.f2",
        ];
        let guided = rng.chance(0.5);
        if guided {
            clauses.push(decodes[rng.below(decodes.len())].to_string());
        }
        let s = clauses.join(";");
        let plan = QueryPlan::parse(&s).expect(&s);
        let rendered = plan.render();
        let reparsed = QueryPlan::parse(&rendered).unwrap();
        assert_eq!(
            reparsed.render(),
            rendered,
            "parse∘render must be the identity (input '{s}')"
        );
        assert_eq!(reparsed, plan, "round-tripped plan must be equal (input '{s}')");
        // the JSON form is equivalent to the grammar form
        assert_eq!(QueryPlan::from_json(&plan.to_json()).unwrap(), plan);
        // Unguided plans must render EXACTLY as they did before the decode
        // stage existed: no decode clause, no reordering of the others.
        if guided {
            assert!(rendered.contains("decode="), "guided plan lost its decode clause ('{s}')");
        } else {
            assert!(
                !rendered.contains("decode"),
                "unguided plan '{s}' rendered a decode clause: '{rendered}'"
            );
            assert_eq!(rendered, s, "unguided render must be byte-identical to its input");
        }
    }
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("plans.snap")
}

fn fmt_ids(ids: &[i32]) -> String {
    ids.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

fn fmt_usizes(ids: &[usize]) -> String {
    ids.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

/// The plan conformance grid: the six paper methods (as lowered plans) plus
/// the hybrid plans, over seeded episodes.
fn snapshot() -> String {
    let (rt, p) = stub_pipeline();
    let mut grid: Vec<(String, QueryPlan)> = all_methods()
        .into_iter()
        .map(|m| {
            let plan = m.to_plan();
            (plan.display_name(), plan)
        })
        .collect();
    for (name, s) in hybrid_plans() {
        grid.push((name.to_string(), QueryPlan::parse(s).unwrap()));
    }
    let mut out = String::new();
    writeln!(out, "# plan conformance snapshot (stub seed {STUB_SEED}, budget {BUDGET})")
        .unwrap();
    for (ei, e) in episodes(&p, &rt).iter().enumerate() {
        for (name, plan) in &grid {
            let r = answer_plan(&p, e, plan);
            writeln!(
                out,
                "ep={ei} plan=\"{}\" name=\"{name}\" answer=[{}] selected=[{}] order=[{}]",
                plan.render(),
                fmt_ids(&r.answer),
                fmt_usizes(&r.selected),
                fmt_usizes(&r.chunk_order),
            )
            .unwrap();
        }
    }
    out
}

#[test]
fn golden_plan_grid_is_pinned() {
    let actual = snapshot();

    // Surface the exercised plans for the CI job summary.
    for m in all_methods() {
        let plan = m.to_plan();
        eprintln!("plan-grid: {} -> {}", plan.display_name(), plan.render());
    }
    for (name, s) in hybrid_plans() {
        eprintln!("plan-grid: {name} -> {s}");
    }

    // Structural sanity: every plan row appears once per episode.
    let n_plans = all_methods().len() + hybrid_plans().len();
    for ei in 0..2 {
        assert_eq!(
            actual.matches(&format!("ep={ei} plan=")).count(),
            n_plans,
            "episode {ei} must cover the whole plan grid"
        );
    }

    // Determinism: an independent runtime/pipeline/store must reproduce
    // the snapshot bit-for-bit.
    assert_eq!(actual, snapshot(), "plan snapshot is not deterministic");

    let path = golden_path();
    if std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &actual).unwrap();
        eprintln!("plan_equivalence: wrote {} (bootstrap)", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).unwrap();
    if expected != actual {
        for (i, (e, a)) in expected.lines().zip(actual.lines()).enumerate() {
            if e != a {
                eprintln!("line {i}:\n  expected: {e}\n  actual:   {a}");
            }
        }
        panic!(
            "plan snapshot drifted from {} — if the change is intentional, \
             regenerate with UPDATE_GOLDEN=1",
            path.display()
        );
    }
}

#[test]
fn hybrid_plans_recombine_stages_not_outcomes() {
    // The hybrids must actually behave like recombinations: a
    // deviation-scored reorder keeps the norm-scored selection's *signal*
    // but may order chunks differently than the pure paper method; and
    // every budgeted hybrid respects its budget.
    let (rt, p) = stub_pipeline();
    for e in &episodes(&p, &rt) {
        for (name, s) in hybrid_plans() {
            let plan = QueryPlan::parse(s).unwrap();
            let r = answer_plan(&p, e, &plan);
            if let Some(sel) = &plan.select {
                if let Some(b) = sel.budget() {
                    assert!(
                        r.selected.len() <= b,
                        "{name}: budget exceeded ({} > {b})",
                        r.selected.len()
                    );
                }
            }
            // reorder stages must still output a permutation
            let mut order = r.chunk_order.clone();
            order.sort_unstable();
            assert_eq!(
                order,
                (0..e.chunks.len()).collect::<Vec<_>>(),
                "{name}: chunk order must be a permutation"
            );
        }
    }
}

#[test]
fn hybrid_plan_serves_end_to_end_with_stage_metrics() {
    use infoflow_kv::coordinator::{Server, ServerConfig};
    // A hybrid plan (inexpressible under the old enum) through the full
    // serving stack: router → batcher → worker pool → pipeline, with
    // per-stage latency blocks keyed by stage name in metrics_json.
    let rt = Arc::new(Runtime::stub(STUB_SEED));
    let mk = || Pipeline::new(ModelSession::new(rt.clone(), "stub").unwrap()).unwrap();
    let workers = vec![mk(), mk()];
    let genr = EpisodeGen::new(workers[0].vocab.clone(), rt.manifest.model.chunk);
    let server = Server::spawn_pool(
        workers,
        ChunkStore::new(1 << 30),
        ServerConfig::default(),
    );
    let plan =
        QueryPlan::parse("reorder=deviation;score=norm:layer2,geom=global;select=topk:8")
            .unwrap();
    // Reference: the same plan answered directly on a local pipeline must
    // match what comes back through the serving stack.
    let reference = mk();
    let mut rng = Rng::new(77);
    for _ in 0..4 {
        let e = genr.onehop(&mut rng, 3);
        let store = ChunkStore::new(1 << 30);
        let (chunks, _) = reference.prepare_chunks(&store, &e.chunks).unwrap();
        let expect = reference.answer_plan(&chunks, &e.prompt, &plan).unwrap();
        let resp = server.query_plan(e, plan.clone()).unwrap();
        assert_eq!(resp.answer, expect.answer, "served answer drifted from local");
        // the response carries the per-stage breakdown of its own plan
        let names: Vec<&str> = resp.stages.iter().map(|(n, _)| *n).collect();
        for want in ["reorder_score", "reorder", "score", "select", "recompute", "prompt", "decode"] {
            assert!(names.contains(&want), "response missing stage '{want}' ({names:?})");
        }
    }
    let dump = server.metrics_json().to_string_pretty();
    for want in ["stage_score", "stage_select", "stage_recompute", "stage_reorder"] {
        assert!(
            dump.contains(want),
            "metrics_json missing per-stage latency block '{want}'"
        );
    }
    server.shutdown();
}
