//! Table 4: VLM benchmark analogs under chunking budgets k in {0, 2, 4}.
//! k = 0 is unchunked baseline inference; for k > 0 the four recompute
//! strategies compete at a fixed token budget.

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::tables::{fmt4, Table};
use crate::eval::EvalRunner;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::vlm::{eval_set, VlmBench};

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let budget = args.usize_or("budget", 16)?;
    let chunk = ctx.runtime.manifest.model.chunk;
    let have = ctx.runtime.backbone_names();
    let backbone = if have.iter().any(|h| h == "qwenvl-syn") {
        "qwenvl-syn".to_string()
    } else {
        ctx.backbone_or_default(args)
    };
    let pipeline = ctx.pipeline(&backbone)?;

    let mut header = vec!["k".to_string(), "Method".to_string()];
    for b in VlmBench::ALL {
        header.push(b.name().to_string());
    }
    let mut table = Table::new(
        &format!("Table 4: VLM comparison ({backbone}, F1, budget {budget})"),
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut json_rows = vec![];

    let mut eval_row = |k: usize, mname: &str, method: MethodSpec| -> Result<()> {
        let mut cells = vec![format!("k={k}"), mname.to_string()];
        let mut jrow = vec![
            ("k", Json::from(k)),
            ("method", Json::from(mname)),
        ];
        for b in VlmBench::ALL {
            let episodes = eval_set(&pipeline.vocab, chunk, b, k, ctx.samples, ctx.seed);
            let store = ctx.store();
            let out = EvalRunner::new(&pipeline, &store).run(&episodes, method)?;
            cells.push(fmt4(out.f1));
            jrow.push((Box::leak(b.name().to_string().into_boxed_str()), Json::from(out.f1)));
        }
        println!("{}", cells.join("  "));
        table.row(cells);
        json_rows.push(Json::obj(jrow));
        Ok(())
    };

    // k = 0: unchunked baseline
    eval_row(0, "Baseline (No Recompute)", MethodSpec::Baseline)?;
    for k in [2usize, 4] {
        eval_row(k, "No Recompute", MethodSpec::NoRecompute)?;
        eval_row(k, "Our", MethodSpec::ours(budget))?;
        eval_row(k, "CacheBlend", MethodSpec::CacheBlend { budget })?;
        eval_row(k, "EPIC", MethodSpec::Epic { budget })?;
    }
    println!("\n{}", table.render());
    ctx.dump("table4", Json::Arr(json_rows), Some(table.to_csv()))?;
    Ok(())
}
