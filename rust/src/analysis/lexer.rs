//! Hand-rolled Rust lexer for the lint pass.
//!
//! Produces a flat code-token stream (comments split out, since the lint
//! control comments — `lint:allow` / `lint:requires` — live there) with
//! 1-based line numbers.  This is a *lint* lexer, not a compiler lexer: it
//! only needs to be exact about the things scope tracking and rule matching
//! depend on — string/char/lifetime disambiguation, raw strings, nested
//! block comments, and identifier boundaries.

/// Token classification.  `Punct` tokens are single characters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A comment (line or block) with the line it starts on.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Lex `src` into (code tokens, comments).  Never fails: unknown bytes
/// become single-character `Punct` tokens, so a pathological file degrades
/// to noise instead of aborting the whole lint run.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let push = |toks: &mut Vec<Tok>, kind, text: &str, line| {
        toks.push(Tok { kind, text: text.to_string(), line });
    };
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = src[i..].find('\n').map_or(n, |o| i + o);
            comments.push(Comment { line, text: src[i..j].to_string() });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            comments.push(Comment { line: start_line, text: src[i..j].to_string() });
            i = j;
            continue;
        }
        // string literals, incl. raw (r"", r#""#) and byte (b"", br"") forms
        if c == b'"' || c == b'r' || c == b'b' {
            let mut j = i;
            let mut is_raw = false;
            let mut hashes = 0usize;
            if j < n && b[j] == b'b' {
                j += 1;
            }
            if j < n && b[j] == b'r' {
                is_raw = true;
                j += 1;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if j < n && b[j] == b'"' {
                j += 1;
                let start_line = line;
                let k = if is_raw {
                    let mut closer = String::from("\"");
                    for _ in 0..hashes {
                        closer.push('#');
                    }
                    let k = src[j..].find(&closer).map_or(n, |o| j + o);
                    line += src[i..k].matches('\n').count() as u32;
                    (k + closer.len()).min(n)
                } else {
                    let mut k = j;
                    while k < n {
                        match b[k] {
                            b'\\' => k += 2,
                            b'"' => {
                                k += 1;
                                break;
                            }
                            b'\n' => {
                                line += 1;
                                k += 1;
                            }
                            _ => k += 1,
                        }
                    }
                    k.min(n)
                };
                push(&mut toks, TokKind::Str, &src[i..k], start_line);
                i = k;
                continue;
            }
            // fall through: identifier starting with r/b, or a bare `"` never
            // reaches here
        }
        // char literal vs lifetime
        if c == b'\'' {
            let j = i + 1;
            if j < n && b[j] == b'\\' {
                let k = src[j + 1..].find('\'').map_or(j + 1, |o| j + 1 + o);
                let end = (k + 1).min(n);
                push(&mut toks, TokKind::Char, &src[i..end], line);
                i = end;
                continue;
            }
            if j + 1 < n && b[j + 1] == b'\'' && b[j] != b'\'' {
                push(&mut toks, TokKind::Char, &src[i..j + 2], line);
                i = j + 2;
                continue;
            }
            let mut k = j;
            while k < n && is_ident_cont(b[k]) {
                k += 1;
            }
            push(&mut toks, TokKind::Lifetime, &src[i..k], line);
            i = k;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            // raw identifier (`r#type`, `r#match`): keep the `r#` prefix in
            // the token text so keyword matching (`match`, `fn`, …) never
            // fires on it, and the `#` never escapes as a stray Punct that
            // would desync attribute/scope tracking
            if c == b'r' && j + 2 < n && b[j + 1] == b'#' && is_ident_start(b[j + 2]) {
                j += 2;
            }
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            push(&mut toks, TokKind::Ident, &src[i..j], line);
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                if is_ident_cont(b[j]) {
                    j += 1;
                    continue;
                }
                // keep a decimal point only when it is followed by a digit
                // (stops at `..` ranges and method calls on literals)
                if b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    continue;
                }
                break;
            }
            push(&mut toks, TokKind::Num, &src[i..j], line);
            i = j;
            continue;
        }
        // consume a full char so slicing stays on UTF-8 boundaries even for
        // non-ASCII bytes in code position
        let ch_len = src[i..].chars().next().map_or(1, char::len_utf8);
        push(&mut toks, TokKind::Punct, &src[i..i + ch_len], line);
        i += ch_len;
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_chars_lifetimes() {
        let ts = kinds(r#"let s = "a\"b"; let c = 'x'; fn f<'a>() {}"#);
        assert!(ts.contains(&(TokKind::Str, "\"a\\\"b\"".into())));
        assert!(ts.contains(&(TokKind::Char, "'x'".into())));
        assert!(ts.contains(&(TokKind::Lifetime, "'a".into())));
    }

    #[test]
    fn raw_strings_and_comments() {
        let (ts, cs) = lex("let s = r#\"no \" end\"#; // tail\n/* b /* nest */ */ x");
        assert!(ts.iter().any(|t| t.kind == TokKind::Str && t.text.starts_with("r#")));
        assert_eq!(cs.len(), 2);
        assert!(ts.iter().any(|t| t.text == "x" && t.line == 2));
    }

    #[test]
    fn non_ascii_degrades_to_punct_without_panicking() {
        let (ts, cs) = lex("let § = 1; // π comment\nlet x = \"résumé ✨\";");
        assert!(ts.iter().any(|t| t.kind == TokKind::Punct && t.text == "§"));
        assert!(ts.iter().any(|t| t.text == "x" && t.line == 2));
        assert_eq!(cs.len(), 1);
    }

    #[test]
    fn raw_identifiers_stay_single_tokens() {
        // `r#type` must be ONE ident (prefix kept, so it never matches the
        // `type` keyword) and must not leak a stray `#` Punct
        let ts = kinds("let r#type = r#match.r#fn(); let r = 1; let s = r#\"raw\"#;");
        assert!(ts.contains(&(TokKind::Ident, "r#type".into())));
        assert!(ts.contains(&(TokKind::Ident, "r#match".into())));
        assert!(ts.contains(&(TokKind::Ident, "r#fn".into())));
        // plain `r` ident and raw strings are untouched
        assert!(ts.contains(&(TokKind::Ident, "r".into())));
        assert!(ts.iter().any(|t| t.0 == TokKind::Str && t.1 == "r#\"raw\"#"));
        // no `#` escaped as punctuation
        assert!(!ts.contains(&(TokKind::Punct, "#".into())));
    }

    #[test]
    fn line_numbers_advance() {
        let (ts, _) = lex("a\nb\n\nc");
        let lines: Vec<u32> = ts.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }
}
