"""AOT artifact contract tests: the manifest the Rust runtime trusts must
exactly describe what the Python side lowers.

These run against the real artifacts/ when present (after `make artifacts`);
the pure-consistency checks (specs vs eval_shape) run always.
"""

import json
import os

import jax
import pytest

from compile.model import ModelConfig, make_entry_points, param_count, param_specs
from compile.aot import BUCKETS
from compile import tasks

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts/ not built")
    with open(path) as f:
        return json.load(f)


class TestManifestContract:
    def test_model_dims_match_default_config(self):
        m = manifest()
        cfg = ModelConfig()
        for field in ("vocab", "d_model", "n_layers", "n_heads", "head_dim",
                      "chunk", "prompt_len", "sel_budget", "answer_buf"):
            assert m["model"][field] == getattr(cfg, field), field
        assert m["param_count"] == param_count(cfg)

    def test_param_layout_matches_specs(self):
        m = manifest()
        cfg = ModelConfig()
        specs = param_specs(cfg)
        assert len(m["param_layout"]) == len(specs)
        for got, (name, shape) in zip(m["param_layout"], specs):
            assert got["name"] == name
            assert tuple(got["shape"]) == tuple(shape)

    def test_vocab_spec_matches_tasks(self):
        m = manifest()
        for k, v in tasks.vocab_spec().items():
            assert m["vocab"][k] == v, k

    def test_every_executable_file_exists_with_args(self):
        m = manifest()
        names = set()
        for e in m["executables"]:
            assert os.path.exists(os.path.join(ART, e["file"])), e["file"]
            assert len(e["args"]) >= 3
            assert len(e["outputs"]) >= 1
            # weights always come first
            assert e["args"][0]["shape"] == [m["param_count"]]
            names.add((e["name"], e["bucket"]))
        for n in BUCKETS:
            for ex in ("score", "recompute", "decode", "deviation", "full_prefill"):
                assert (ex, n) in names
        assert ("prefill_chunk", None) in names

    def test_backbone_weights_exist_and_sized(self):
        m = manifest()
        assert m["backbones"], "no backbones — training incomplete"
        for name, b in m["backbones"].items():
            path = os.path.join(ART, b["weights"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) == m["param_count"] * 4, name


class TestSpecConsistency:
    """Pure checks: manifest arg specs are generated from the same example
    args that get lowered, so eval_shape must agree for every entry point."""

    @pytest.mark.parametrize("n_ctx", [128, 256])
    def test_entry_point_outputs_are_stable(self, n_ctx):
        cfg = ModelConfig()
        eps = make_entry_points(cfg, n_ctx, use_pallas=False)
        fn, args = eps["score"]
        score_out = jax.eval_shape(fn, *args)
        leaves = jax.tree.leaves(score_out)
        # scores, prompt_k, prompt_v, last_logits
        assert tuple(leaves[0].shape) == (cfg.n_layers, n_ctx)
        assert tuple(leaves[1].shape) == (
            cfg.n_layers, cfg.prompt_len, cfg.n_heads, cfg.head_dim)
        assert tuple(leaves[3].shape) == (cfg.vocab,)
        rfn, rargs = eps["recompute"]
        rec_out = jax.tree.leaves(jax.eval_shape(rfn, *rargs))
        assert tuple(rec_out[0].shape) == (
            cfg.n_layers, cfg.sel_budget, cfg.n_heads, cfg.head_dim)
        dfn, dargs = eps["decode"]
        dec_out = jax.tree.leaves(jax.eval_shape(dfn, *dargs))
        assert tuple(dec_out[0].shape) == (cfg.vocab,)

    def test_weights_param_is_first_and_flat(self):
        cfg = ModelConfig()
        eps = make_entry_points(cfg, 128, use_pallas=False)
        for name, (_fn, args) in eps.items():
            assert args[0].shape == (param_count(cfg),), name
