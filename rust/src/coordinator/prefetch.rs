//! Priority prefetch queue: the router's background-warm jobs, ordered by
//! **distance to dispatch** instead of arrival.
//!
//! The old job channel was FIFO, so a burst's last request warmed no later
//! than its first — even though the first is about to hit a worker and the
//! last will sit through several batch windows.  Here every job carries the
//! owning request's position in the batcher queue (0 = next to dispatch),
//! the prefetchers always pop the smallest distance, and the router's
//! post-dispatch re-peek RE-prioritizes jobs already queued (a request that
//! just moved to the front of the line pulls its chunks' warm forward).
//!
//! Mechanics: slot-addressed jobs + a lazy-deletion binary heap keyed by
//! `(priority, seq)` — seq keeps FIFO order within a priority and
//! invalidates superseded heap entries after a reprioritize.  `pop` blocks
//! on a condvar; after [`PrefetchQueue::close`] it drains what is queued
//! and then returns `None`, which is what lets server shutdown finish every
//! scheduled warm instead of dropping the tail.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::{Condvar, Mutex};

use crate::kvcache::ChunkId;

/// A prefetch job: one request's chunk token lists (minus anything already
/// queued for prefetch), plus their content ids so the prefetcher can clear
/// the router's queued-set when the warm completes.
pub struct PrefetchJob {
    pub ids: Vec<ChunkId>,
    pub chunks: Vec<Vec<i32>>,
}

struct QueuedJob {
    job: PrefetchJob,
    prio: u64,
    /// seq of this slot's newest heap entry; older entries are stale.
    seq: u64,
}

struct State {
    /// Slot-addressed jobs (`None` = vacant; stale heap entries may still
    /// name the slot and are skipped on pop).
    slots: Vec<Option<QueuedJob>>,
    free: Vec<usize>,
    /// Min-heap of (priority, seq, slot): smallest distance-to-dispatch
    /// first, FIFO within a priority.
    heap: BinaryHeap<Reverse<(u64, u64, usize)>>,
    /// Any queued chunk id → the slot of the job carrying it (jobs never
    /// share an id: the router's queued-set dedups at admission).
    by_id: HashMap<ChunkId, usize>,
    seq: u64,
    len: usize,
    cap: usize,
    closed: bool,
}

/// Bounded, closable, priority-ordered MPMC job queue.
pub struct PrefetchQueue {
    state: Mutex<State>,
    cv: Condvar,
}

impl PrefetchQueue {
    pub fn new(cap: usize) -> PrefetchQueue {
        PrefetchQueue {
            state: Mutex::new(State {
                slots: Vec::new(),
                free: Vec::new(),
                heap: BinaryHeap::new(),
                by_id: HashMap::new(),
                seq: 0,
                len: 0,
                cap: cap.max(1),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue a job at `prio` (0 = dispatching next).  A full or closed
    /// queue hands the job back — the router drops the hint (and un-queues
    /// its ids) rather than ever stalling on the prefetch path.
    pub fn push(&self, job: PrefetchJob, prio: u64) -> Result<(), PrefetchJob> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.len >= st.cap {
            return Err(job);
        }
        let slot = match st.free.pop() {
            Some(s) => s,
            None => {
                st.slots.push(None);
                st.slots.len() - 1
            }
        };
        st.seq += 1;
        let seq = st.seq;
        for &id in &job.ids {
            st.by_id.insert(id, slot);
        }
        st.slots[slot] = Some(QueuedJob { job, prio, seq });
        st.heap.push(Reverse((prio, seq, slot)));
        st.len += 1;
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Pull the queued job containing `id` forward to `prio` if that is
    /// MORE urgent than its current priority (a re-peek can only move work
    /// earlier; arrival order never worsens).  Returns whether anything
    /// changed — `false` also covers ids that are mid-warm or unknown.
    pub fn reprioritize(&self, id: ChunkId, prio: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        let Some(&slot) = st.by_id.get(&id) else {
            return false;
        };
        st.seq += 1;
        let seq = st.seq;
        let Some(qj) = st.slots[slot].as_mut() else {
            return false;
        };
        if prio >= qj.prio {
            return false;
        }
        qj.prio = prio;
        qj.seq = seq;
        st.heap.push(Reverse((prio, seq, slot)));
        drop(st);
        self.cv.notify_one();
        true
    }

    /// Blocking pop of the most urgent job.  `None` only after
    /// [`PrefetchQueue::close`] AND the queue has drained — every job
    /// admitted before close is still handed out.
    pub fn pop(&self) -> Option<PrefetchJob> {
        let mut st = self.state.lock().unwrap();
        loop {
            while let Some(Reverse((_prio, seq, slot))) = st.heap.pop() {
                let live = st.slots[slot]
                    .as_ref()
                    .is_some_and(|qj| qj.seq == seq);
                if !live {
                    continue; // stale (superseded or already popped) entry
                }
                let Some(qj) = st.slots[slot].take() else {
                    continue; // unreachable given `live`, but stay panic-free
                };
                st.free.push(slot);
                st.len -= 1;
                for id in &qj.job.ids {
                    st.by_id.remove(id);
                }
                return Some(qj.job);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop admission and wake every parked popper; queued jobs keep being
    /// served until the queue is empty.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn job(tag: i32, ids: &[u64]) -> PrefetchJob {
        PrefetchJob {
            ids: ids.to_vec(),
            chunks: vec![vec![tag, tag + 1, tag + 2]],
        }
    }

    #[test]
    fn pops_by_distance_to_dispatch_not_arrival() {
        let q = PrefetchQueue::new(8);
        q.push(job(10, &[1]), 5).unwrap();
        q.push(job(20, &[2]), 0).unwrap();
        q.push(job(30, &[3]), 2).unwrap();
        q.close();
        let order: Vec<i32> = std::iter::from_fn(|| q.pop())
            .map(|j| j.chunks[0][0])
            .collect();
        assert_eq!(order, vec![20, 30, 10], "front-of-queue requests warm first");
    }

    #[test]
    fn fifo_within_a_priority() {
        let q = PrefetchQueue::new(8);
        for (tag, id) in [(10, 1u64), (20, 2), (30, 3)] {
            q.push(job(tag, &[id]), 7).unwrap();
        }
        q.close();
        let order: Vec<i32> = std::iter::from_fn(|| q.pop())
            .map(|j| j.chunks[0][0])
            .collect();
        assert_eq!(order, vec![10, 20, 30], "equal priorities keep arrival order");
    }

    #[test]
    fn repeek_reprioritization_pulls_a_job_forward() {
        let q = PrefetchQueue::new(8);
        q.push(job(10, &[1]), 1).unwrap();
        q.push(job(20, &[2, 3]), 3).unwrap();
        // the re-peek finds the second request now heading the batcher
        assert!(q.reprioritize(3, 0), "queued id must be movable");
        // worsening is refused; unknown ids are a no-op
        assert!(!q.reprioritize(2, 9));
        assert!(!q.reprioritize(77, 0));
        q.close();
        let order: Vec<i32> = std::iter::from_fn(|| q.pop())
            .map(|j| j.chunks[0][0])
            .collect();
        assert_eq!(order, vec![20, 10], "reprioritized job must jump the line");
        assert!(!q.reprioritize(3, 0), "popped ids are no longer addressable");
    }

    #[test]
    fn capacity_bounds_and_closed_queue_reject() {
        let q = PrefetchQueue::new(1);
        q.push(job(10, &[1]), 0).unwrap();
        assert!(q.push(job(20, &[2]), 0).is_err(), "full queue hands the job back");
        assert_eq!(q.len(), 1);
        q.close();
        assert!(q.push(job(30, &[3]), 0).is_err(), "closed queue rejects admission");
        assert!(q.pop().is_some(), "close still drains what was queued");
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn blocked_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(PrefetchQueue::new(4));
        let qc = q.clone();
        let h = std::thread::spawn(move || {
            let first = qc.pop().map(|j| j.chunks[0][0]);
            let second = qc.pop().map(|j| j.chunks[0][0]);
            (first, second)
        });
        std::thread::sleep(Duration::from_millis(10));
        q.push(job(10, &[1]), 0).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        let (first, second) = h.join().unwrap();
        assert_eq!(first, Some(10), "parked pop must wake on push");
        assert_eq!(second, None, "parked pop must wake on close");
    }

    #[test]
    fn slots_are_recycled_across_churn() {
        let q = PrefetchQueue::new(2);
        for round in 0..50u64 {
            q.push(job(round as i32, &[round * 2]), round % 3).unwrap();
            q.push(job(round as i32, &[round * 2 + 1]), round % 5).unwrap();
            assert!(q.pop().is_some());
            assert!(q.pop().is_some());
        }
        assert_eq!(q.state.lock().unwrap().slots.len(), 2, "slots must be reused");
        q.close();
        assert!(q.pop().is_none());
    }
}
