//! Recomputation-target selection strategies.
//!
//! * [`topk`] — generic top-k over per-token scores: the back half of the
//!   paper's Eq. 8 (`S = Top-k({s_j})`); used with attention-norm scores
//!   (ours) and deviation scores (CacheBlend).
//! * [`epic`] — EPIC's fixed positional heuristic (chunk-initial tokens).
//! * [`per_chunk_topk`] — stage-1 of the reordering strategy (§4.3): best
//!   tokens within each chunk independently.

/// Indices of the `k` highest-scoring valid rows, in descending score order.
/// Ties break toward lower indices (deterministic).
pub fn topk(scores: &[f32], valid: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len())
        .filter(|&i| valid[i] > 0.0 && scores[i].is_finite())
        .collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// EPIC-style selection: an even split of `budget` across chunk-initial
/// tokens (document-boundary rows), in chunk-major order.  Budget left over
/// by chunks shorter than their share is redistributed across the remaining
/// chunks, so the full budget is always spent: the result has exactly
/// `budget.min(total_rows)` rows.
pub fn epic(chunk_lens: &[usize], budget: usize) -> Vec<usize> {
    let total: usize = chunk_lens.iter().sum();
    let budget = budget.min(total);
    if chunk_lens.is_empty() || budget == 0 {
        return vec![];
    }
    // Water-filling: repeatedly split what remains evenly over the chunks
    // that still have unclaimed rows.  Each round either exhausts the
    // budget or saturates at least one chunk, so this terminates in at
    // most `chunk_lens.len()` rounds.
    let mut take = vec![0usize; chunk_lens.len()];
    let mut remaining = budget;
    while remaining > 0 {
        let unsaturated: Vec<usize> = (0..chunk_lens.len())
            .filter(|&i| take[i] < chunk_lens[i])
            .collect();
        if unsaturated.is_empty() {
            break;
        }
        let per = remaining.div_ceil(unsaturated.len());
        for i in unsaturated {
            let add = per.min(chunk_lens[i] - take[i]).min(remaining);
            take[i] += add;
            remaining -= add;
            if remaining == 0 {
                break;
            }
        }
    }
    let mut out = Vec::with_capacity(budget);
    let mut base = 0usize;
    for (i, &len) in chunk_lens.iter().enumerate() {
        out.extend(base..base + take[i]);
        base += len;
    }
    out
}

/// Top-`m` rows of each chunk by score (for chunk-level importance and the
/// reorder stage-1 pass). Returns per-chunk index lists (global row indices).
pub fn per_chunk_topk(
    scores: &[f32],
    valid: &[f32],
    chunk_lens: &[usize],
    m: usize,
) -> Vec<Vec<usize>> {
    let mut out = Vec::with_capacity(chunk_lens.len());
    let mut base = 0usize;
    for &len in chunk_lens {
        let local = topk(&scores[base..base + len], &valid[base..base + len], m);
        out.push(local.into_iter().map(|i| base + i).collect());
        base += len;
    }
    out
}

/// Chunk importance = sum of its top-`m` token scores (§4.3).
pub fn chunk_scores(
    scores: &[f32],
    valid: &[f32],
    chunk_lens: &[usize],
    m: usize,
) -> Vec<f32> {
    per_chunk_topk(scores, valid, chunk_lens, m)
        .iter()
        .map(|rows| rows.iter().map(|&i| scores[i]).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn topk_orders_and_respects_validity() {
        let scores = [0.1, 0.9, 0.5, 0.7];
        let valid = [1.0, 0.0, 1.0, 1.0];
        assert_eq!(topk(&scores, &valid, 2), vec![3, 2]);
        assert_eq!(topk(&scores, &valid, 10), vec![3, 2, 0]);
    }

    #[test]
    fn topk_tie_breaks_low_index() {
        let scores = [0.5, 0.5, 0.5];
        let valid = [1.0, 1.0, 1.0];
        assert_eq!(topk(&scores, &valid, 2), vec![0, 1]);
    }

    #[test]
    fn epic_picks_chunk_heads() {
        // 2 chunks of 4, budget 4 -> first 2 of each
        assert_eq!(epic(&[4, 4], 4), vec![0, 1, 4, 5]);
        // budget 3 -> the first chunk gets the odd row out
        assert_eq!(epic(&[4, 4], 3), vec![0, 1, 4]);
        assert_eq!(epic(&[], 4), Vec::<usize>::new());
    }

    #[test]
    fn epic_redistributes_short_chunk_leftovers() {
        // Chunk 0 saturates at 1 row; its unused share must flow to chunk 1
        // so the whole budget is spent (the old code returned 4 rows here).
        assert_eq!(epic(&[1, 8], 6), vec![0, 1, 2, 3, 4, 5]);
        // Budget larger than the context selects everything.
        assert_eq!(epic(&[2, 2], 10), vec![0, 1, 2, 3]);
        // Middle chunk short, both neighbors absorb the leftovers.
        assert_eq!(epic(&[4, 1, 4], 9), vec![0, 1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn per_chunk_topk_stays_in_chunk() {
        let scores = [0.0, 9.0, 0.0, 0.0, 8.0, 0.1, 0.0, 0.0];
        let valid = [1.0; 8];
        let sel = per_chunk_topk(&scores, &valid, &[4, 4], 1);
        assert_eq!(sel, vec![vec![1], vec![4]]);
    }

    #[test]
    fn chunk_scores_sum_top_m() {
        let scores = [1.0, 2.0, 0.0, 5.0, 4.0, 0.0];
        let valid = [1.0; 6];
        let cs = chunk_scores(&scores, &valid, &[3, 3], 2);
        assert_eq!(cs, vec![3.0, 9.0]);
    }

    #[test]
    fn properties() {
        prop::check(150, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let valid: Vec<f32> =
                (0..n).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
            let k = rng.below(n + 4);
            let sel = topk(&scores, &valid, k);
            let n_valid = valid.iter().filter(|&&v| v > 0.0).count();
            prop::assert_prop(sel.len() == k.min(n_valid), "size")?;
            // distinct
            let mut s2 = sel.clone();
            s2.sort_unstable();
            s2.dedup();
            prop::assert_prop(s2.len() == sel.len(), "duplicates")?;
            // descending scores, all valid
            for w in sel.windows(2) {
                prop::assert_prop(scores[w[0]] >= scores[w[1]], "order")?;
            }
            for &i in &sel {
                prop::assert_prop(valid[i] > 0.0, "invalid row selected")?;
            }
            // every unselected valid row scores <= the worst selected row
            if let Some(&last) = sel.last() {
                for i in 0..n {
                    if valid[i] > 0.0 && !sel.contains(&i) {
                        prop::assert_prop(
                            scores[i] <= scores[last],
                            "missed a better row",
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn epic_budget_property() {
        prop::check(100, |rng: &mut Rng| {
            let k = 1 + rng.below(8);
            let lens: Vec<usize> = (0..k).map(|_| 1 + rng.below(64)).collect();
            let n: usize = lens.iter().sum();
            let budget = rng.below(n + 8);
            let sel = epic(&lens, budget);
            prop::assert_prop(
                sel.len() == budget.min(n),
                format!("budget not spent: {} != {}", sel.len(), budget.min(n)),
            )?;
            let mut sorted = sel.clone();
            sorted.sort_unstable();
            sorted.dedup();
            prop::assert_prop(sorted.len() == sel.len(), "duplicates")?;
            prop::assert_prop(sel.iter().all(|&i| i < n), "out of range")
        });
    }
}
