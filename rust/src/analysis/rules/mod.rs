//! The five repo-specific lint rules, one module per rule, plus the call-
//! shape helpers they share.  Each rule encodes an invariant this codebase
//! was burned by in an earlier PR — see CONTRIBUTING.md "Invariants &
//! lints" for the rule-by-rule history.

pub mod channel_hygiene;
pub mod counter_discipline;
pub mod flight_section;
pub mod guard_blocking;
pub mod panic_surface;

use super::lexer::{Tok, TokKind};

/// Rule identifiers as they appear in diagnostics and `lint:allow(...)`.
pub const GUARD_ACROSS_BLOCKING: &str = "guard-across-blocking";
pub const PANIC_SURFACE: &str = "panic-surface";
pub const COUNTER_DISCIPLINE: &str = "counter-discipline";
pub const CHANNEL_HYGIENE: &str = "channel-hygiene";
pub const FLIGHT_CRITICAL_SECTION: &str = "flight-critical-section";
/// Malformed `lint:allow` comments (missing/empty reason) — not
/// suppressible, by design.
pub const ALLOW_SYNTAX: &str = "allow-syntax";

/// Every rule, in reporting order.
pub const ALL_RULES: [&str; 6] = [
    GUARD_ACROSS_BLOCKING,
    PANIC_SURFACE,
    COUNTER_DISCIPLINE,
    CHANNEL_HYGIENE,
    FLIGHT_CRITICAL_SECTION,
    ALLOW_SYNTAX,
];

/// Is token `i` immediately followed by `(`?
pub(crate) fn is_call(toks: &[Tok], i: usize) -> bool {
    toks.get(i + 1).is_some_and(|t| t.text == "(")
}

/// Does the call whose `(` is at `open_idx` have zero arguments?
pub(crate) fn args_empty(toks: &[Tok], open_idx: usize) -> bool {
    toks.get(open_idx + 1).is_some_and(|t| t.text == ")")
}

/// Is token `i` a method call (`.name(`)?
pub(crate) fn is_method_call(toks: &[Tok], i: usize) -> bool {
    i >= 1 && toks[i - 1].text == "." && is_call(toks, i)
}

/// The identifier immediately before the `.` at `dot_idx` — the last
/// segment of the receiver.  `None` for chained-call receivers (`…)(.`).
pub(crate) fn receiver_name(toks: &[Tok], dot_idx: usize) -> Option<&str> {
    if dot_idx == 0 {
        return None;
    }
    let prev = &toks[dot_idx - 1];
    if prev.kind == TokKind::Ident {
        Some(&prev.text)
    } else {
        None
    }
}
