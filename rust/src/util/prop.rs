//! Property-test runner (proptest-lite, first-party for the offline build).
//!
//! Runs a property against many seeded random cases; on failure it reports
//! the failing case number and seed so the case can be replayed exactly:
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = 1 + rng.below(100);
//!     let mut v = ...;
//!     prop::assert_prop(invariant(&v), format!("violated for n={n}"))
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

pub fn assert_prop(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop`. Panics (test failure) on the first
/// violated case, printing the seed for replay.
pub fn check(cases: usize, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    check_seeded(0xC0FFEE, cases, &mut prop);
}

pub fn check_seeded(
    base_seed: u64,
    cases: usize,
    prop: &mut impl FnMut(&mut Rng) -> PropResult,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property violated (case {case}/{cases}, replay seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        check(50, |_| Ok(()));
    }

    #[test]
    fn exercises_rng_cases() {
        let mut seen = std::collections::HashSet::new();
        check(50, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 50, "each case must get a distinct stream");
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn fails_loudly() {
        check(10, |rng| {
            assert_prop(rng.below(10) < 5, "found a counterexample >= 5")
        });
    }
}
