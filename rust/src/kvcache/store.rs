//! The chunk KV store: offline-prefilled chunk caches keyed by content id,
//! with LRU eviction under a byte budget, pin counting, hit/miss accounting
//! and a simple binary persistence format so caches survive restarts
//! (the paper's "prefetched offline and reused across queries" regime).
//!
//! The store is internally synchronized and sharded by [`ChunkId`] so the
//! multi-worker coordinator can hit it concurrently: every operation takes
//! `&self`, locks exactly one shard, and holds the lock only for the
//! get/insert itself — never across prefill or answer.  Recency is tracked
//! with a per-shard monotonic counter (O(1) touch; eviction scans the shard
//! for the oldest unpinned entry, which is rare and shard-local), replacing
//! the old `Vec::position` LRU list.
//!
//! On top of the resident tier the store owns the **chunk lifecycle**:
//!
//! * an optional disk **spill tier** ([`super::tier::SpillTier`]): eviction
//!   serializes the chunk to a per-chunk file instead of discarding it, and
//!   a later miss deserializes it back (bit-identical) instead of paying a
//!   full prefill;
//! * a per-chunk **single-flight registry**: concurrent misses of the same
//!   id share ONE resolution (prefill or spill admission) — followers block
//!   on the leader's flight slot instead of duplicating the work, proven by
//!   the [`LifecycleStats::duplicate_prefills`] tripwire counter;
//! * [`ChunkStore::get_or_load`], the miss-resolution entry point the
//!   pipeline and the coordinator's prefetcher both go through.
//!
//! Invariant maintained across all of it: a chunk id is never resident in
//! the store and spilled on disk at the same time (admission removes the
//! file before inserting; eviction removes the entry before writing, under
//! the id's flight slot).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::kvcache::tier::SpillTier;
use crate::rope;
use crate::tensor::TensorF;
use crate::util::json::Json;

pub type ChunkId = u64;

/// Default shard count: enough to keep 4-8 workers from contending while
/// keeping per-shard budgets comfortably larger than a chunk.
pub const DEFAULT_SHARDS: usize = 8;

/// Largest tensor rank the persistence format will accept (real chunk KV is
/// rank 4); guards `load` against allocating from garbage headers.
const MAX_RANK: usize = 8;

/// Positional provenance of a chunk's stored key rows — the IFKV record
/// domain flag.  The serving paths produce and expect [`KeyDomain::Unrotated`]
/// everywhere; [`KeyDomain::RotatedLocal`] survives only long enough for the
/// store-level migration of legacy `IFKV1` records to un-rotate it away.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KeyDomain {
    /// Keys rotated to their chunk-local positions at prefill time — the
    /// pre-deferred-RoPE storage format, produced only by legacy `IFKV1`
    /// records on read.
    RotatedLocal = 0,
    /// Position-free keys: raw, unrotated, unquantized.  RoPE is applied at
    /// the attention boundary ([`rope::materialize_row`]), which is what
    /// lets the same bytes serve ANY positional layout.
    #[default]
    Unrotated = 1,
}

impl KeyDomain {
    pub fn from_u32(x: u32) -> Option<KeyDomain> {
        match x {
            0 => Some(KeyDomain::RotatedLocal),
            1 => Some(KeyDomain::Unrotated),
            _ => None,
        }
    }
}

/// An immutable prefilled chunk: tokens + position-free KV states.
#[derive(Clone, Debug)]
pub struct ChunkKv {
    pub id: ChunkId,
    pub tokens: Vec<i32>,
    /// [n_layers, C, H, Dh] keys, position-free (see `key_domain`): raw
    /// unrotated rows that every positional layout shares.
    // lint:domain(unrotated)
    pub k: TensorF,
    /// [n_layers, C, H, Dh] values.
    pub v: TensorF,
    /// Positional provenance of `k` (the IFKV record domain flag).
    pub key_domain: KeyDomain,
}

impl ChunkKv {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn nbytes(&self) -> usize {
        self.tokens.len() * 4 + (self.k.len() + self.v.len()) * 4
    }

    /// Content-derived id (FNV-1a over the token stream) so identical
    /// documents share one cache entry across queries.
    pub fn content_id(tokens: &[i32]) -> ChunkId {
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in tokens {
            for b in t.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        h
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub bytes: usize,
    /// Byte budget actually assigned (sums exactly to the requested total
    /// across shards — no remainder is dropped by the shard split).
    pub budget_bytes: usize,
    /// Resident bytes held by pin-counted entries.  Pinned bytes live
    /// INSIDE `bytes`/`budget_bytes` accounting: a pinned chunk is counted
    /// resident, exempt from eviction, and can never be spilled.
    pub pinned_bytes: usize,
    /// Resident entries with a non-zero pin count.
    pub pinned_chunks: u64,
}

impl StoreStats {
    fn merge(&mut self, other: &StoreStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.insertions += other.insertions;
        self.evictions += other.evictions;
        self.bytes += other.bytes;
        self.budget_bytes += other.budget_bytes;
        self.pinned_bytes += other.pinned_bytes;
        self.pinned_chunks += other.pinned_chunks;
    }
}

/// Cross-thread lifecycle honesty counters.  `kvcache::counters` is
/// thread-local by design; miss resolution is inherently cross-thread, so
/// these live as atomics on the store itself.
#[derive(Debug, Default)]
pub struct LifecycleStats {
    /// Loader (prefill) invocations performed via [`ChunkStore::get_or_load`].
    pub prefills: AtomicU64,
    /// Loader invocations that completed while the chunk was ALREADY
    /// resident — exactly the wasted work the single-flight registry exists
    /// to prevent.  Must read 0 when every miss goes through `get_or_load`.
    pub duplicate_prefills: AtomicU64,
    /// Misses satisfied by deserializing a spilled chunk instead of a
    /// prefill (the disk tier's "hits").
    pub spill_admits: AtomicU64,
    /// Evicted chunks serialized to the spill tier.
    pub spills: AtomicU64,
    /// Spill/admission IO failures (the chunk falls back to re-prefill).
    pub spill_errors: AtomicU64,
    /// Followers that blocked on another thread's in-flight resolution.
    pub single_flight_waits: AtomicU64,
    /// Chunks admitted through [`ChunkStore::admit`] (bulk restores routed
    /// through the flight-aware lifecycle path).
    pub restores: AtomicU64,
    /// Legacy `IFKV1` records migrated to the position-free key domain on
    /// entry: their chunk-local RoPE was inverted host-side so every resident
    /// chunk is uniformly [`KeyDomain::Unrotated`].
    pub migrations: AtomicU64,
}

impl LifecycleStats {
    fn json(&self) -> Json {
        let g = |a: &AtomicU64| Json::from(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("prefills", g(&self.prefills)),
            ("duplicate_prefills", g(&self.duplicate_prefills)),
            ("spill_admits", g(&self.spill_admits)),
            ("spills", g(&self.spills)),
            ("spill_errors", g(&self.spill_errors)),
            ("single_flight_waits", g(&self.single_flight_waits)),
            ("restores", g(&self.restores)),
            ("migrations", g(&self.migrations)),
        ])
    }
}

/// Per-chunk single-flight registry: at most one thread resolves a given
/// chunk id at a time (prefill, spill admission, or spill write); everyone
/// else either waits on the leader's slot or skips.
#[derive(Default)]
struct Flights {
    slots: Mutex<HashMap<ChunkId, Arc<FlightSlot>>>,
}

#[derive(Default)]
struct FlightSlot {
    done: Mutex<bool>,
    cv: Condvar,
}

impl FlightSlot {
    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }
}

enum FlightTicket {
    Leader,
    Follower(Arc<FlightSlot>),
}

impl Flights {
    fn begin(&self, id: ChunkId) -> FlightTicket {
        let mut g = self.slots.lock().unwrap();
        match g.get(&id) {
            Some(slot) => FlightTicket::Follower(slot.clone()),
            None => {
                g.insert(id, Arc::new(FlightSlot::default()));
                FlightTicket::Leader
            }
        }
    }

    /// Non-blocking: become leader for `id` or give up immediately.
    fn try_begin(&self, id: ChunkId) -> bool {
        let mut g = self.slots.lock().unwrap();
        if g.contains_key(&id) {
            return false;
        }
        g.insert(id, Arc::new(FlightSlot::default()));
        true
    }

    fn end(&self, id: ChunkId) {
        let slot = self.slots.lock().unwrap().remove(&id);
        if let Some(s) = slot {
            *s.done.lock().unwrap() = true;
            s.cv.notify_all();
        }
    }
}

/// Ends the flight (waking all followers) even when the leader errors out.
struct FlightGuard<'a> {
    flights: &'a Flights,
    id: ChunkId,
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.flights.end(self.id);
    }
}

struct Entry {
    chunk: Arc<ChunkKv>,
    /// Shard-local recency tick; larger = more recently used.
    last_used: u64,
    /// Store-level pin count ([`ChunkStore::pin`]).  Non-zero exempts the
    /// entry from eviction (so it can never spill) while keeping its bytes
    /// inside the shard's budget accounting — unlike a caller-held `Arc`,
    /// which also blocks eviction but is invisible to `metrics_json`.
    pins: u32,
}

struct Shard {
    budget_bytes: usize,
    entries: HashMap<ChunkId, Entry>,
    /// Resident bytes, maintained incrementally.
    bytes: usize,
    /// Monotonic recency counter.
    tick: u64,
    stats: StoreStats,
}

impl Shard {
    fn new(budget_bytes: usize) -> Shard {
        Shard {
            budget_bytes,
            entries: HashMap::new(),
            bytes: 0,
            tick: 0,
            stats: StoreStats::default(),
        }
    }

    /// Evict oldest unpinned entries until the shard fits its budget,
    /// returning the evicted chunks so the caller can spill them to disk
    /// OUTSIDE the shard lock.  The entry being inserted right now carries
    /// one extra strong count (the `Arc` that `insert()` is about to hand
    /// back).
    fn evict_to_budget(&mut self, inserting: Option<ChunkId>) -> Vec<Arc<ChunkKv>> {
        let mut victims = Vec::new();
        while self.bytes > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .filter(|entry| {
                    let unpinned = if inserting == Some(*entry.0) { 2 } else { 1 };
                    entry.1.pins == 0 && Arc::strong_count(&entry.1.chunk) == unpinned
                })
                .min_by_key(|entry| entry.1.last_used)
                .map(|entry| *entry.0);
            match victim {
                Some(id) => {
                    if let Some(e) = self.entries.remove(&id) {
                        self.bytes -= e.chunk.nbytes();
                        self.stats.evictions += 1;
                        victims.push(e.chunk);
                    }
                }
                // Everything left is pinned by in-flight requests.
                None => break,
            }
        }
        victims
    }
}

/// Copy a shard's counters plus its live residency/pin/budget state (read
/// under the caller's shard lock).
fn snapshot_shard(g: &Shard) -> StoreStats {
    let mut s = g.stats;
    s.bytes = g.bytes;
    s.budget_bytes = g.budget_bytes;
    for e in g.entries.values() {
        if e.pins > 0 {
            s.pinned_chunks += 1;
            s.pinned_bytes += e.chunk.nbytes();
        }
    }
    s
}

/// Sharded LRU chunk cache with a byte budget, safe to share across worker
/// threads as `Arc<ChunkStore>`.  Entries handed out as `Arc` stay alive
/// while in use; eviction skips entries that are externally pinned.
///
/// The total budget is split evenly across shards, so it should be much
/// larger than `shards * chunk_bytes`; pass `with_shards(budget, 1)` for the
/// exact single-LRU semantics (useful in deterministic tests).
pub struct ChunkStore {
    shards: Vec<Mutex<Shard>>,
    /// `shards.len() - 1`; shard count is always a power of two.
    shard_mask: usize,
    /// Cumulative nanoseconds spent waiting to acquire shard locks.
    lock_wait_ns: AtomicU64,
    /// Optional disk tier: evictions spill here, misses re-admit from here.
    spill: Option<Arc<SpillTier>>,
    /// Per-chunk single-flight slots for miss resolution and spill writes.
    flights: Flights,
    life: LifecycleStats,
    /// Inserts that evicted the chunk they had just inserted: the shard
    /// budget is below one chunk, so the store is thrashing instead of
    /// caching.  Degenerate-budget warning counter (`stats_json`).
    thrash_evictions: AtomicU64,
    /// True when the constructor clamped the shard count down to keep
    /// per-shard budgets non-zero (budget below one byte per shard).
    shards_clamped: bool,
    /// RoPE theta used to invert chunk-local rotation when migrating legacy
    /// `IFKV1` ([`KeyDomain::RotatedLocal`]) records.  The legacy record
    /// format never persisted theta, so deployments that prefilled with a
    /// non-default base must set it via [`ChunkStore::set_migration_theta`]
    /// before restoring old snapshots.
    migration_theta: f64,
}

impl ChunkStore {
    pub fn new(budget_bytes: usize) -> ChunkStore {
        ChunkStore::with_shards(budget_bytes, DEFAULT_SHARDS)
    }

    /// `n_shards` is rounded up to a power of two (min 1); the byte budget
    /// is distributed EXACTLY across shards — the first `budget % n` shards
    /// take one extra byte, so per-shard budgets sum to `budget_bytes`
    /// instead of silently dropping up to `n - 1` bytes.  A degenerate
    /// budget below one byte per shard clamps the shard count down (to the
    /// largest power of two with a non-zero per-shard budget) instead of
    /// creating 0-byte shards that evict every insert instantly; the clamp
    /// is warned once and surfaced as `shards_clamped` in `stats_json`.
    pub fn with_shards(budget_bytes: usize, n_shards: usize) -> ChunkStore {
        let mut n = n_shards.max(1).next_power_of_two();
        let mut clamped = false;
        while n > 1 && budget_bytes / n == 0 {
            n /= 2;
            clamped = true;
        }
        if clamped {
            eprintln!(
                "[kvcache] budget {budget_bytes}B is below one byte per shard; \
                 clamping {n_shards} shards down to {n}"
            );
        }
        let base = budget_bytes / n;
        let extra = budget_bytes % n;
        ChunkStore {
            shards: (0..n)
                .map(|i| Mutex::new(Shard::new(base + usize::from(i < extra))))
                .collect(),
            shard_mask: n - 1,
            lock_wait_ns: AtomicU64::new(0),
            spill: None,
            flights: Flights::default(),
            life: LifecycleStats::default(),
            thrash_evictions: AtomicU64::new(0),
            shards_clamped: clamped,
            migration_theta: 10000.0,
        }
    }

    /// Override the RoPE base used to un-rotate legacy `IFKV1` records (the
    /// v1 format did not persist theta).  Irrelevant for `IFKV2` records,
    /// which are already position-free on disk.
    pub fn set_migration_theta(&mut self, theta: f64) {
        self.migration_theta = theta;
    }

    /// Normalize a chunk entering the store to the position-free key domain.
    ///
    /// Legacy `IFKV1` records stored `quantize(rotate(raw, t))` per row; the
    /// serving path now expects raw unrotated keys, so we invert the
    /// chunk-local rotation host-side.  Rotation is an isometry, so the
    /// inverse is exact up to the quantization noise already baked into the
    /// legacy bytes (< 2^-12 per element) — acceptable for legacy-only data,
    /// and re-snapped onto the grid at the attention seam anyway.
    fn migrate_domain(&self, mut chunk: ChunkKv) -> ChunkKv {
        if chunk.key_domain != KeyDomain::RotatedLocal {
            return chunk;
        }
        let shape = chunk.k.shape().to_vec();
        if shape.len() != 4 {
            // Unknown layout: leave the record untouched rather than guess.
            return chunk;
        }
        let (layers, c, heads, dh) = (shape[0], shape[1], shape[2], shape[3]);
        let data = chunk.k.data_mut();
        for li in 0..layers {
            for t in 0..c {
                let base = (li * c + t) * heads * dh;
                for h in 0..heads {
                    let s = base + h * dh;
                    // lint:allow(position-domain, reason="legacy IFKV1 migration runs the local->global converter backwards (negative delta) to STRIP chunk-local rotation from stored keys; this is the one sanctioned un-rotation site")
                    rope::rotate(&mut data[s..s + dh], -(t as i64), self.migration_theta);
                }
            }
        }
        chunk.key_domain = KeyDomain::Unrotated;
        self.life.migrations.fetch_add(1, Ordering::Relaxed);
        chunk
    }

    /// A sharded store with a disk spill tier attached.
    pub fn with_spill(
        budget_bytes: usize,
        n_shards: usize,
        tier: Arc<SpillTier>,
    ) -> ChunkStore {
        let mut s = ChunkStore::with_shards(budget_bytes, n_shards);
        s.set_spill_tier(tier);
        s
    }

    /// Attach a disk spill tier (before the store is shared): evictions
    /// serialize to it and [`ChunkStore::get_or_load`] re-admits from it
    /// instead of re-prefilling.
    pub fn set_spill_tier(&mut self, tier: Arc<SpillTier>) {
        self.spill = Some(tier);
    }

    pub fn spill_tier(&self) -> Option<&SpillTier> {
        self.spill.as_deref()
    }

    /// Lifecycle counters (single-flight + spill-tier accounting).
    pub fn lifecycle(&self) -> &LifecycleStats {
        &self.life
    }

    /// Whether someone is resolving `id` right now.  Best-effort (the
    /// answer can be stale by the time the caller acts on it); used by the
    /// prefetcher to skip chunks a worker is already loading instead of
    /// parking on their flight slots.
    pub fn in_flight(&self, id: ChunkId) -> bool {
        self.flights.slots.lock().unwrap().contains_key(&id)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard_index(&self, id: ChunkId) -> usize {
        // Content ids are already hashes, but mix anyway so adversarial or
        // structured ids (tests use 0,1,2,..) still spread across shards.
        let mixed = id.wrapping_mul(0x9E3779B97F4A7C15);
        ((mixed >> 32) as usize) & self.shard_mask
    }

    /// Lock the shard owning `id`, accounting the wait time.
    fn lock_shard(&self, id: ChunkId) -> MutexGuard<'_, Shard> {
        let t0 = Instant::now();
        let g = self.shards[self.shard_index(id)].lock().unwrap();
        self.lock_wait_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        g
    }

    /// Total seconds any caller has spent blocked on shard locks.
    pub fn lock_wait_s(&self) -> f64 {
        self.lock_wait_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Aggregate stats across all shards.
    pub fn stats(&self) -> StoreStats {
        let mut total = StoreStats::default();
        for shard in &self.shards {
            total.merge(&snapshot_shard(&shard.lock().unwrap()));
        }
        total
    }

    /// Per-shard stats (hit/eviction balance, residency skew).
    pub fn shard_stats(&self) -> Vec<StoreStats> {
        self.shards
            .iter()
            .map(|shard| snapshot_shard(&shard.lock().unwrap()))
            .collect()
    }

    /// Stats as JSON for the serving metrics dump.
    pub fn stats_json(&self) -> Json {
        let agg = self.stats();
        let shard_objs: Vec<Json> = self
            .shard_stats()
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("hits", Json::from(s.hits as f64)),
                    ("misses", Json::from(s.misses as f64)),
                    ("evictions", Json::from(s.evictions as f64)),
                    ("bytes", Json::from(s.bytes)),
                ])
            })
            .collect();
        let mut entries = vec![
            ("hits", Json::from(agg.hits as f64)),
            ("misses", Json::from(agg.misses as f64)),
            ("insertions", Json::from(agg.insertions as f64)),
            ("evictions", Json::from(agg.evictions as f64)),
            ("bytes", Json::from(agg.bytes)),
            ("budget_bytes", Json::from(agg.budget_bytes)),
            ("pinned_bytes", Json::from(agg.pinned_bytes)),
            ("pinned_chunks", Json::from(agg.pinned_chunks as f64)),
            (
                "thrash_evictions",
                Json::from(self.thrash_evictions.load(Ordering::Relaxed) as f64),
            ),
            ("shards_clamped", Json::from(self.shards_clamped)),
            ("lock_wait_ms", Json::from(self.lock_wait_s() * 1e3)),
            ("shards", Json::Arr(shard_objs)),
            ("lifecycle", self.life.json()),
        ];
        if let Some(tier) = &self.spill {
            entries.push(("spill_tier", tier.stats_json()));
        }
        Json::obj(entries)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, id: ChunkId) -> bool {
        self.shards[self.shard_index(id)]
            .lock()
            .unwrap()
            .entries
            .contains_key(&id)
    }

    pub fn get(&self, id: ChunkId) -> Option<Arc<ChunkKv>> {
        let mut guard = self.lock_shard(id);
        let sh = &mut *guard;
        sh.tick += 1;
        match sh.entries.get_mut(&id) {
            Some(e) => {
                e.last_used = sh.tick;
                sh.stats.hits += 1;
                Some(e.chunk.clone())
            }
            None => {
                sh.stats.misses += 1;
                None
            }
        }
    }

    /// Uncounted lookup (no hit/miss accounting): used by the lifecycle
    /// machinery for re-checks, so stats keep meaning "one logical lookup,
    /// one hit-or-miss".
    fn probe(&self, id: ChunkId) -> Option<Arc<ChunkKv>> {
        let mut guard = self.lock_shard(id);
        let sh = &mut *guard;
        sh.tick += 1;
        let tick = sh.tick;
        sh.entries.get_mut(&id).map(|e| {
            e.last_used = tick;
            e.chunk.clone()
        })
    }

    /// Pin a resident chunk: while any pin is held the entry is exempt from
    /// eviction (and therefore can never be spilled), and its bytes stay
    /// inside the shard's `bytes`/`budget_bytes` accounting — visible as
    /// `pinned_bytes`/`pinned_chunks` in [`ChunkStore::stats_json`].
    /// Returns `false` when the id is not resident (callers should fall
    /// back to re-loading rather than assuming residency).
    pub fn pin(&self, id: ChunkId) -> bool {
        let mut guard = self.lock_shard(id);
        match guard.entries.get_mut(&id) {
            Some(e) => {
                e.pins = e.pins.saturating_add(1);
                true
            }
            None => false,
        }
    }

    /// Release one pin.  Returns `false` when the id was absent or had no
    /// pins (pin/unpin calls must balance; unpin never underflows).  When
    /// the last pin drops, the entry rejoins LRU order and the shard is
    /// settled back under its budget immediately (victims spill as usual).
    pub fn unpin(&self, id: ChunkId) -> bool {
        let (released, victims) = {
            let mut guard = self.lock_shard(id);
            let sh = &mut *guard;
            let released = match sh.entries.get_mut(&id) {
                Some(e) if e.pins > 0 => {
                    e.pins -= 1;
                    true
                }
                _ => false,
            };
            let victims =
                if released { sh.evict_to_budget(None) } else { Vec::new() };
            (released, victims)
        };
        self.spill_evicted(victims);
        released
    }

    pub fn insert(&self, chunk: ChunkKv) -> Arc<ChunkKv> {
        let id = chunk.id;
        let arc = Arc::new(chunk);
        let victims = {
            let mut guard = self.lock_shard(id);
            let sh = &mut *guard;
            sh.tick += 1;
            // A replaced entry keeps its pin count: ids are content hashes,
            // so the bytes (and the pinned contract) carry over unchanged.
            let pins = sh.entries.get(&id).map(|e| e.pins).unwrap_or(0);
            let entry = Entry { chunk: arc.clone(), last_used: sh.tick, pins };
            sh.bytes += arc.nbytes();
            if let Some(old) = sh.entries.insert(id, entry) {
                // Concurrent workers may race to prefill the same content id;
                // last write wins and the accounting stays balanced.
                sh.bytes -= old.chunk.nbytes();
            }
            sh.stats.insertions += 1;
            sh.evict_to_budget(Some(id))
        };
        if victims.iter().any(|v| v.id == id) {
            // The insert evicted the chunk it just inserted: this shard's
            // budget is below one chunk and the store is thrashing.
            self.thrash_evictions.fetch_add(1, Ordering::Relaxed);
        }
        self.spill_victims(id, victims);
        arc
    }

    /// Spill freshly evicted chunks to the disk tier, outside every shard
    /// lock.  Each victim is written under its own single-flight slot so a
    /// concurrent `get_or_load` of the same id either wins (and we skip the
    /// spill — it is about to be resident again) or only sees the finished
    /// file.
    fn spill_victims(&self, inserted: ChunkId, victims: Vec<Arc<ChunkKv>>) {
        let Some(tier) = &self.spill else { return };
        // An insert of a previously spilled id makes that file stale; drop
        // it so no chunk stays resident and spilled at the same time.  This
        // WAITS for the id's flight if one is active — almost always just a
        // spill write in progress (admission and loader flights consume the
        // id's file up front), so raw inserts effectively never block; only
        // the lifecycle API is hot-path anyway.
        if tier.contains(inserted) {
            loop {
                match self.flights.begin(inserted) {
                    FlightTicket::Leader => {
                        // lint:allow(lock-order, reason="stale-file discard flight: unreachable while any flight is held — under-flight inserts never have a spill file for the id (tier.contains is false), and raw insert callers hold no flight")
                        let _g = FlightGuard { flights: &self.flights, id: inserted };
                        tier.discard(inserted);
                        break;
                    }
                    FlightTicket::Follower(slot) => slot.wait(),
                }
            }
        }
        self.spill_evicted(victims);
    }

    /// Write evicted chunks to the disk tier, outside every shard lock.
    /// Shared by insert-driven eviction and unpin-driven settling.
    fn spill_evicted(&self, victims: Vec<Arc<ChunkKv>>) {
        let Some(tier) = &self.spill else { return };
        for v in victims {
            if !self.flights.try_begin(v.id) {
                // Someone is resolving this id right now; spilling a chunk
                // that is about to be resident again would break the
                // resident-xor-spilled invariant.  Skip it.
                continue;
            }
            // lint:allow(lock-order, reason="victim spill flights are try_begin-reserved: contended ids are skipped, never waited on, so adopting this slot while a caller holds another flight cannot deadlock")
            let _g = FlightGuard { flights: &self.flights, id: v.id };
            self.spill_one(tier, &v);
        }
    }

    /// Write one evicted chunk to the tier.  MUST be called with the
    /// chunk's flight held.  Re-checks residency around the write so an
    /// insert racing the eviction always ends with exactly one live copy.
    // lint:requires(flight)
    fn spill_one(&self, tier: &Arc<SpillTier>, chunk: &Arc<ChunkKv>) {
        if self.probe(chunk.id).is_some() {
            return; // re-inserted between eviction and spill
        }
        match tier.spill(chunk) {
            Ok(()) => {
                self.life.spills.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                self.life.spill_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("[kvcache] spill of chunk {:#018x} failed: {e:#}", chunk.id);
            }
        }
        if self.probe(chunk.id).is_some() {
            // An insert raced the write (it will have found our flight busy
            // and skipped its own cleanup, or blocked until we release);
            // the resident copy wins.
            tier.discard(chunk.id);
        }
    }

    /// Insert a chunk whose flight slot the CALLING thread holds.  If the
    /// insertion instantly evicted the chunk again (budget smaller than the
    /// live working set), spill it under our own flight — `spill_victims`
    /// had to skip it because the slot was taken (by us) — so the chunk is
    /// moved to disk instead of silently dropped.
    // lint:requires(flight)
    fn insert_under_flight(&self, chunk: ChunkKv) -> Arc<ChunkKv> {
        let id = chunk.id;
        let arc = self.insert(chunk);
        if let Some(tier) = &self.spill {
            // `insert` saw our flight on this id and skipped both the
            // stale-file check (no file exists on any under-flight path)
            // and, had we been evicted, the victim spill — so do the spill
            // ourselves while we still own the slot.
            self.spill_one(tier, &arc);
        }
        arc
    }

    /// The lifecycle miss-resolution API: return the resident chunk, or
    /// re-admit it from the spill tier, or run `load` (a prefill) — with
    /// concurrent callers for the same id sharing ONE resolution through
    /// the single-flight registry.  [`LifecycleStats::duplicate_prefills`]
    /// stays 0 exactly when no prefill work was ever duplicated.
    ///
    /// `load` runs outside every lock; only the per-id flight slot is held
    /// across it, so loads of *different* chunks proceed in parallel.
    ///
    /// Protocol note: with a spill tier attached, raw [`ChunkStore::insert`]
    /// remains safe for bulk load/restore, but mixing raw inserts and
    /// `get_or_load` for the SAME id concurrently can leave a transient
    /// redundant spill file (content-identical by construction, since ids
    /// are content hashes).  The lifecycle API alone maintains the strict
    /// resident-xor-spilled invariant.
    pub fn get_or_load(
        &self,
        id: ChunkId,
        load: impl FnOnce() -> Result<ChunkKv>,
    ) -> Result<Arc<ChunkKv>> {
        if let Some(c) = self.get(id) {
            return Ok(c);
        }
        let mut load = Some(load);
        loop {
            match self.flights.begin(id) {
                FlightTicket::Leader => {
                    let _guard = FlightGuard { flights: &self.flights, id };
                    // A previous leader may have finished between our miss
                    // and taking the flight.
                    if let Some(c) = self.probe(id) {
                        return Ok(c);
                    }
                    if let Some(tier) = &self.spill {
                        match tier.take(id) {
                            Ok(Some(chunk)) => {
                                self.life.spill_admits.fetch_add(1, Ordering::Relaxed);
                                let chunk = self.migrate_domain(chunk);
                                return Ok(self.insert_under_flight(chunk));
                            }
                            Ok(None) => {}
                            Err(e) => {
                                self.life.spill_errors.fetch_add(1, Ordering::Relaxed);
                                eprintln!(
                                    "[kvcache] admitting chunk {id:#018x} failed ({e:#}); re-prefilling"
                                );
                            }
                        }
                    }
                    let load = load.take().ok_or_else(|| {
                        anyhow!("chunk {id:#018x}: loader consumed by an earlier attempt")
                    })?;
                    self.life.prefills.fetch_add(1, Ordering::Relaxed);
                    let chunk = load()?;
                    if chunk.id != id {
                        bail!(
                            "loader produced chunk {:#018x} for id {id:#018x}",
                            chunk.id
                        );
                    }
                    if self.contains(id) {
                        // Unreachable through this API; the counter is the
                        // tripwire the concurrency tests assert on.
                        self.life.duplicate_prefills.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(self.insert_under_flight(chunk));
                }
                FlightTicket::Follower(slot) => {
                    self.life.single_flight_waits.fetch_add(1, Ordering::Relaxed);
                    slot.wait();
                    if let Some(c) = self.probe(id) {
                        return Ok(c);
                    }
                    // The leader failed (or the chunk was already evicted
                    // again): take the lead ourselves on the next spin.
                }
            }
        }
    }

    /// Admit a fully materialized chunk through the flight-aware lifecycle
    /// path — the bulk-restore counterpart of [`ChunkStore::get_or_load`].
    /// Unlike raw [`ChunkStore::insert`], this serializes with any live
    /// resolution of the same id and removes a stale spill-tier file before
    /// inserting, so restores compose with a live spill tier without ever
    /// leaving a chunk resident and spilled at once.
    ///
    /// If the id is already resident the existing entry is returned
    /// untouched (ids are content hashes, so the copies are identical).
    pub fn admit(&self, chunk: ChunkKv) -> Arc<ChunkKv> {
        let chunk = self.migrate_domain(chunk);
        let id = chunk.id;
        loop {
            match self.flights.begin(id) {
                FlightTicket::Leader => {
                    let _guard = FlightGuard { flights: &self.flights, id };
                    if let Some(existing) = self.probe(id) {
                        return existing;
                    }
                    // Consume any spilled copy up front (under our flight),
                    // exactly like the admission path of `get_or_load`; the
                    // incoming chunk supersedes it.
                    if let Some(tier) = &self.spill {
                        tier.discard(id);
                    }
                    self.life.restores.fetch_add(1, Ordering::Relaxed);
                    return self.insert_under_flight(chunk);
                }
                FlightTicket::Follower(slot) => {
                    self.life.single_flight_waits.fetch_add(1, Ordering::Relaxed);
                    slot.wait();
                    if let Some(existing) = self.probe(id) {
                        return existing;
                    }
                    // The other resolution failed or was evicted again:
                    // take the lead ourselves on the next spin.
                }
            }
        }
    }

    // -- persistence ---------------------------------------------------------
    // Record format (little-endian), shared with the spill tier
    // (`kvcache::tier`): magic "IFKV2\0\0\0" once per file, then per chunk:
    //   id u64 | n_tokens u32 | k_rank u32 | key_domain u32 | k dims u32* |
    //   tokens i32* | k f32* | v f32*   (v has the same dims as k)
    //
    // Writers always emit v2.  Readers also accept legacy "IFKV1\0\0\0"
    // files, whose records have no key_domain field and whose keys carry
    // chunk-local RoPE; those records are migrated to the position-free
    // domain on admission (`migrate_domain`).

    pub fn save(&self, path: &Path) -> Result<()> {
        // Snapshot under per-shard locks, write outside them.  Entries go
        // out oldest-first so a reload rebuilds the same per-shard recency.
        let mut snapshot: Vec<(u64, Arc<ChunkKv>)> = Vec::new();
        for shard in &self.shards {
            let g = shard.lock().unwrap();
            snapshot.extend(g.entries.values().map(|e| (e.last_used, e.chunk.clone())));
        }
        snapshot.sort_by_key(|e| (e.0, e.1.id));
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow!("creating {}: {e}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(STORE_MAGIC)?;
        for (_, e) in &snapshot {
            write_chunk_record(&mut w, e.as_ref())?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: &Path, budget_bytes: usize) -> Result<ChunkStore> {
        ChunkStore::load_with_shards(path, budget_bytes, DEFAULT_SHARDS)
    }

    /// Stream the store file chunk-by-chunk through a buffered reader:
    /// startup memory is bounded by ONE chunk, not the whole file (stores
    /// are routinely orders of magnitude larger than a chunk).
    pub fn load_with_shards(
        path: &Path,
        budget_bytes: usize,
        n_shards: usize,
    ) -> Result<ChunkStore> {
        let store = ChunkStore::with_shards(budget_bytes, n_shards);
        store.restore_from(path)?;
        Ok(store)
    }

    /// Stream a persisted store file into this (possibly live) store through
    /// the flight-aware [`ChunkStore::admit`] path, returning how many
    /// records were read.  Restores therefore compose with a live spill
    /// tier and with concurrent `get_or_load` traffic: every admitted id
    /// serializes under its single-flight slot, stale spill files are
    /// consumed, and already-resident ids are left untouched.
    pub fn restore_from(&self, path: &Path) -> Result<usize> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
        let total = f.metadata()?.len();
        if total < 8 {
            bail!("{}: bad magic", path.display());
        }
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        let v2 = if &magic == STORE_MAGIC {
            true
        } else if &magic == STORE_MAGIC_V1 {
            false
        } else {
            bail!("{}: bad magic", path.display());
        };
        let mut n = 0usize;
        let mut remaining = total - 8;
        while let Some(chunk) = read_chunk_record(&mut r, &mut remaining, v2)
            .map_err(|e| anyhow!("{}: {e:#}", path.display()))?
        {
            self.admit(chunk);
            n += 1;
        }
        Ok(n)
    }
}

/// Current on-disk format: records carry a key-domain flag, keys are stored
/// position-free.  Written by every save/spill path.
pub(crate) const STORE_MAGIC: &[u8; 8] = b"IFKV2\0\0\0";

/// Legacy on-disk format: no domain flag, keys under chunk-local RoPE.
/// Accepted on read only; records are migrated on admission.
pub(crate) const STORE_MAGIC_V1: &[u8; 8] = b"IFKV1\0\0\0";

/// Serialize one chunk record (no magic — that is per file) to `w`.
pub(crate) fn write_chunk_record<W: Write>(w: &mut W, c: &ChunkKv) -> Result<()> {
    w.write_all(&c.id.to_le_bytes())?;
    w.write_all(&(c.tokens.len() as u32).to_le_bytes())?;
    w.write_all(&(c.k.shape().len() as u32).to_le_bytes())?;
    w.write_all(&(c.key_domain as u32).to_le_bytes())?;
    for &d in c.k.shape() {
        w.write_all(&(d as u32).to_le_bytes())?;
    }
    for &t in &c.tokens {
        w.write_all(&t.to_le_bytes())?;
    }
    for &x in c.k.data() {
        w.write_all(&x.to_le_bytes())?;
    }
    for &x in c.v.data() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Fill `buf` from `r`, distinguishing clean EOF (zero bytes read, `false`)
/// from a mid-record truncation (hard error).
fn read_full_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0usize;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(false);
            }
            bail!("truncated chunk record");
        }
        got += n;
    }
    Ok(true)
}

fn rd_u32<R: Read>(r: &mut R, remaining: &mut u64) -> Result<u32> {
    let mut b = [0u8; 4];
    if !read_full_or_eof(r, &mut b)? {
        bail!("truncated chunk header");
    }
    *remaining = remaining.saturating_sub(4);
    Ok(u32::from_le_bytes(b))
}

fn rd_f32s<R: Read>(r: &mut R, n: usize, remaining: &mut u64) -> Result<Vec<f32>> {
    let mut b = vec![0u8; n * 4];
    if !read_full_or_eof(r, &mut b)? {
        bail!("truncated chunk body");
    }
    *remaining = remaining.saturating_sub(b.len() as u64);
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Deserialize the next chunk record from `r`, or `None` at clean EOF.
/// `remaining` tracks how many payload bytes the stream can still supply, so
/// a corrupt header can never provoke an over-allocation: memory use is
/// bounded by one plausible chunk regardless of what the header claims.
pub(crate) fn read_chunk_record<R: Read>(
    r: &mut R,
    remaining: &mut u64,
    v2: bool,
) -> Result<Option<ChunkKv>> {
    let mut idb = [0u8; 8];
    if !read_full_or_eof(r, &mut idb)? {
        return Ok(None);
    }
    *remaining = remaining.saturating_sub(8);
    let id = u64::from_le_bytes(idb);
    let n_tokens = rd_u32(r, remaining)? as usize;
    let rank = rd_u32(r, remaining)? as usize;
    if rank > MAX_RANK {
        bail!("implausible tensor rank {rank} (corrupt file?)");
    }
    let key_domain = if v2 {
        let raw = rd_u32(r, remaining)?;
        KeyDomain::from_u32(raw)
            .ok_or_else(|| anyhow!("unknown key domain {raw} (corrupt file?)"))?
    } else {
        // v1 records predate the flag: keys carry chunk-local RoPE.
        KeyDomain::RotatedLocal
    };
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(rd_u32(r, remaining)? as usize);
    }
    // All size arithmetic checked: garbage headers must produce an error,
    // not an overflow-wrapped bound that lets an allocation explode.
    let n_kv = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow!("tensor dims overflow (corrupt file?)"))?;
    let need = n_tokens
        .checked_mul(4)
        .and_then(|t| n_kv.checked_mul(8).and_then(|kv| t.checked_add(kv)))
        .ok_or_else(|| anyhow!("chunk size overflow (corrupt file?)"))?;
    if need as u64 > *remaining {
        bail!("truncated chunk body (record wants {need} bytes, {remaining} left)");
    }
    let mut tb = vec![0u8; n_tokens * 4];
    if !read_full_or_eof(r, &mut tb)? {
        bail!("truncated chunk body");
    }
    *remaining = remaining.saturating_sub(tb.len() as u64);
    let tokens: Vec<i32> = tb
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let k = TensorF::from_vec(&dims, rd_f32s(r, n_kv, remaining)?)?;
    let v = TensorF::from_vec(&dims, rd_f32s(r, n_kv, remaining)?)?;
    Ok(Some(ChunkKv { id, tokens, k, v, key_domain }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn mk_chunk(id: ChunkId, c: usize) -> ChunkKv {
        let dims = [2usize, c, 2, 4];
        let n: usize = dims.iter().product();
        ChunkKv {
            id,
            tokens: (0..c as i32).collect(),
            k: TensorF::from_vec(&dims, (0..n).map(|x| x as f32).collect()).unwrap(),
            v: TensorF::from_vec(&dims, (0..n).map(|x| (x * 2) as f32).collect()).unwrap(),
            key_domain: KeyDomain::Unrotated,
        }
    }

    #[test]
    fn hit_miss_accounting() {
        let s = ChunkStore::new(usize::MAX);
        s.insert(mk_chunk(1, 8));
        assert!(s.get(1).is_some());
        assert!(s.get(2).is_none());
        let st = s.stats();
        assert_eq!((st.hits, st.misses, st.insertions), (1, 1, 1));
    }

    #[test]
    fn evicts_lru_first() {
        // Single shard: deterministic global LRU order.
        let one = mk_chunk(1, 8).nbytes();
        let s = ChunkStore::with_shards(2 * one, 1);
        s.insert(mk_chunk(1, 8));
        s.insert(mk_chunk(2, 8));
        let _ = s.get(1); // make 2 the LRU
        s.insert(mk_chunk(3, 8)); // exceeds budget -> evict 2
        assert!(s.contains(1));
        assert!(!s.contains(2));
        assert!(s.contains(3));
        assert_eq!(s.stats().evictions, 1);
    }

    #[test]
    fn pinned_entries_survive_eviction() {
        let one = mk_chunk(1, 8).nbytes();
        let s = ChunkStore::with_shards(one, 1); // room for 1 entry
        let pinned = s.insert(mk_chunk(1, 8));
        s.insert(mk_chunk(2, 8));
        // 1 is pinned (we hold an Arc) so 2 must go instead
        assert!(s.contains(1));
        assert!(!s.contains(2));
        drop(pinned);
        s.insert(mk_chunk(3, 8));
        assert!(!s.contains(1), "unpinned LRU entry finally evicted");
    }

    #[test]
    fn reinsert_same_id_keeps_bytes_balanced() {
        let s = ChunkStore::with_shards(usize::MAX, 1);
        let one = mk_chunk(4, 8).nbytes();
        s.insert(mk_chunk(4, 8));
        s.insert(mk_chunk(4, 8)); // racing double-prefill: last write wins
        assert_eq!(s.len(), 1);
        assert_eq!(s.stats().bytes, one);
        assert_eq!(s.stats().insertions, 2);
    }

    #[test]
    fn content_id_stable_and_sensitive() {
        let a = ChunkKv::content_id(&[1, 2, 3]);
        assert_eq!(a, ChunkKv::content_id(&[1, 2, 3]));
        assert_ne!(a, ChunkKv::content_id(&[1, 2, 4]));
        assert_ne!(a, ChunkKv::content_id(&[3, 2, 1]));
    }

    #[test]
    fn entries_spread_across_shards() {
        let s = ChunkStore::with_shards(usize::MAX, 4);
        for i in 0..64u64 {
            s.insert(mk_chunk(i, 8));
        }
        let per_shard = s.shard_stats();
        assert_eq!(per_shard.len(), 4);
        assert_eq!(per_shard.iter().map(|st| st.insertions).sum::<u64>(), 64);
        let populated = per_shard.iter().filter(|st| st.bytes > 0).count();
        assert!(populated >= 3, "ids clumped onto {populated}/4 shards");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ifkv_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunks.bin");
        let s = ChunkStore::new(usize::MAX);
        s.insert(mk_chunk(7, 4));
        s.insert(mk_chunk(9, 4));
        s.save(&path).unwrap();
        let l = ChunkStore::load(&path, usize::MAX).unwrap();
        assert_eq!(l.len(), 2);
        let c = l.get(7).unwrap();
        assert_eq!(c.tokens, (0..4).collect::<Vec<i32>>());
        assert_eq!(c.k.shape(), &[2, 4, 2, 4]);
        let orig = mk_chunk(7, 4);
        assert_eq!(c.k.max_abs_diff(&orig.k), 0.0);
        assert_eq!(c.v.max_abs_diff(&orig.v), 0.0);
        assert_eq!(c.key_domain, KeyDomain::Unrotated);
        assert_eq!(l.lifecycle().migrations.load(Ordering::Relaxed), 0);
        std::fs::remove_file(&path).ok();
    }

    /// Serialize one record in the LEGACY v1 layout (no key_domain field).
    fn write_v1_record(v: &mut Vec<u8>, c: &ChunkKv) {
        v.extend_from_slice(&c.id.to_le_bytes());
        v.extend_from_slice(&(c.tokens.len() as u32).to_le_bytes());
        v.extend_from_slice(&(c.k.shape().len() as u32).to_le_bytes());
        for &d in c.k.shape() {
            v.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &t in &c.tokens {
            v.extend_from_slice(&t.to_le_bytes());
        }
        for &x in c.k.data() {
            v.extend_from_slice(&x.to_le_bytes());
        }
        for &x in c.v.data() {
            v.extend_from_slice(&x.to_le_bytes());
        }
    }

    #[test]
    fn legacy_v1_records_migrate_to_unrotated_on_load() {
        let dir = std::env::temp_dir().join("ifkv_store_v1_migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy.bin");
        // Raw position-free chunk, then its legacy twin with every key row
        // rotated to its chunk-local position (what v1 prefill stored).
        let (layers, c, heads, dh) = (2usize, 4usize, 2usize, 4usize);
        let mut rng = Rng::new(42);
        let n = layers * c * heads * dh;
        let raw_k: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut legacy_k = raw_k.clone();
        for li in 0..layers {
            for t in 0..c {
                let base = (li * c + t) * heads * dh;
                for h in 0..heads {
                    let s = base + h * dh;
                    crate::rope::rotate(&mut legacy_k[s..s + dh], t as i64, 10000.0);
                }
            }
        }
        let dims = [layers, c, heads, dh];
        let legacy = ChunkKv {
            id: 11,
            tokens: (0..c as i32).collect(),
            k: TensorF::from_vec(&dims, legacy_k).unwrap(),
            v: TensorF::from_vec(&dims, (0..n).map(|x| x as f32).collect()).unwrap(),
            key_domain: KeyDomain::RotatedLocal,
        };
        let mut bytes = b"IFKV1\0\0\0".to_vec();
        write_v1_record(&mut bytes, &legacy);
        std::fs::write(&path, &bytes).unwrap();

        let l = ChunkStore::load(&path, usize::MAX).unwrap();
        let got = l.get(11).unwrap();
        assert_eq!(got.key_domain, KeyDomain::Unrotated);
        assert_eq!(l.lifecycle().migrations.load(Ordering::Relaxed), 1);
        // Un-rotation inverts the legacy rotation up to f32 rounding.
        let raw = TensorF::from_vec(&dims, raw_k).unwrap();
        let err = got.k.max_abs_diff(&raw);
        assert!(err < 1e-4, "migration residual {err}");
        assert_eq!(got.v.max_abs_diff(&legacy.v), 0.0, "values must be untouched");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_records_round_trip_domain_flag_bit_identically() {
        let dir = std::env::temp_dir().join("ifkv_store_v2_domain");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v2.bin");
        let s = ChunkStore::new(usize::MAX);
        s.insert(mk_chunk(3, 4));
        s.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"IFKV2\0\0\0", "writers must emit v2");
        let l = ChunkStore::load(&path, usize::MAX).unwrap();
        let got = l.get(3).unwrap();
        assert_eq!(got.key_domain, KeyDomain::Unrotated);
        // No migration ran: the record was already position-free, and its
        // key bytes round-tripped untouched.
        assert_eq!(l.lifecycle().migrations.load(Ordering::Relaxed), 0);
        assert_eq!(got.k.max_abs_diff(&mk_chunk(3, 4).k), 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_corrupt_files_without_panicking() {
        let dir = std::env::temp_dir().join("ifkv_store_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("empty", vec![]),
            ("bad_magic", b"NOTKV000".to_vec()),
            ("magic_only_truncated_header", b"IFKV1\0\0\0\x01\x02".to_vec()),
            ("truncated_after_id", {
                let mut v = b"IFKV1\0\0\0".to_vec();
                v.extend_from_slice(&7u64.to_le_bytes());
                v
            }),
            ("absurd_rank", {
                let mut v = b"IFKV1\0\0\0".to_vec();
                v.extend_from_slice(&7u64.to_le_bytes());
                v.extend_from_slice(&1u32.to_le_bytes()); // n_tokens
                v.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
                v
            }),
            ("dims_product_overflow", {
                let mut v = b"IFKV1\0\0\0".to_vec();
                v.extend_from_slice(&7u64.to_le_bytes());
                v.extend_from_slice(&1u32.to_le_bytes()); // n_tokens
                v.extend_from_slice(&4u32.to_le_bytes()); // rank 4
                for _ in 0..4 {
                    v.extend_from_slice(&u32::MAX.to_le_bytes()); // dims
                }
                v
            }),
            ("truncated_body", {
                let mut v = b"IFKV1\0\0\0".to_vec();
                v.extend_from_slice(&7u64.to_le_bytes());
                v.extend_from_slice(&8u32.to_le_bytes()); // n_tokens
                v.extend_from_slice(&2u32.to_le_bytes()); // rank 2
                v.extend_from_slice(&4u32.to_le_bytes());
                v.extend_from_slice(&4u32.to_le_bytes());
                v.extend_from_slice(&[0u8; 12]); // far short of 8*4 + 2*16*4
                v
            }),
        ];
        for (name, data) in cases {
            let path = dir.join(name);
            std::fs::write(&path, &data).unwrap();
            let res = ChunkStore::load(&path, usize::MAX);
            assert!(res.is_err(), "{name}: corrupt file must not load");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn load_rejects_corruption_mid_stream_after_valid_chunks() {
        // Streaming load must parse leading valid records and still reject
        // the file when a LATER record is corrupt — without ever allocating
        // more than one chunk's worth of payload for the bad header.
        let dir = std::env::temp_dir().join("ifkv_store_midstream");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mid.bin");
        let s = ChunkStore::new(usize::MAX);
        s.insert(mk_chunk(1, 4));
        s.insert(mk_chunk(2, 4));
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // A third record whose header claims an absurd rank.
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&4u32.to_le_bytes()); // n_tokens
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
        std::fs::write(&path, &bytes).unwrap();
        let err = ChunkStore::load(&path, usize::MAX).unwrap_err();
        assert!(
            format!("{err:#}").contains("rank"),
            "mid-stream corruption must surface the header error, got: {err:#}"
        );
        // And a record claiming a body far larger than the file remainder.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 16); // drop the absurd-rank record
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&1_000_000u32.to_le_bytes()); // n_tokens
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        bytes.extend_from_slice(&1_000_000u32.to_le_bytes()); // dim
        std::fs::write(&path, &bytes).unwrap();
        assert!(
            ChunkStore::load(&path, usize::MAX).is_err(),
            "body larger than the file remainder must be rejected before allocation"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage_tail_after_valid_chunk() {
        let dir = std::env::temp_dir().join("ifkv_store_tail");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tail.bin");
        let s = ChunkStore::new(usize::MAX);
        s.insert(mk_chunk(7, 4));
        s.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0xAB; 5]); // partial next header
        std::fs::write(&path, &bytes).unwrap();
        assert!(ChunkStore::load(&path, usize::MAX).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_get_insert_evict_smoke() {
        let one = mk_chunk(0, 8).nbytes();
        // Budget forces steady eviction churn under contention.
        let store = Arc::new(ChunkStore::with_shards(4 * 16 * one, 4));
        let gets = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let store = store.clone();
            let gets = gets.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                let mut pinned = Vec::new();
                for i in 0..200u64 {
                    let id = rng.below(48) as u64;
                    if rng.chance(0.5) {
                        let arc = store.insert(mk_chunk(id, 8));
                        if rng.chance(0.2) {
                            pinned.push(arc); // hold some pins across ops
                        }
                    } else {
                        let _ = store.get(id);
                        gets.fetch_add(1, Ordering::Relaxed);
                    }
                    if i % 50 == 0 {
                        pinned.clear();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = store.stats();
        assert_eq!(st.hits + st.misses, gets.load(Ordering::Relaxed));
        assert!(!store.is_empty());
        // All pins are dropped; one more insert per shard settles each
        // shard back under its budget.
        for id in 0..64u64 {
            store.insert(mk_chunk(id, 8));
        }
        assert!(store.stats().bytes <= 4 * 16 * one);
    }

    #[test]
    fn lru_property_never_exceeds_budget_when_unpinned() {
        prop::check(50, |rng: &mut Rng| {
            let one = mk_chunk(0, 8).nbytes();
            let cap = 1 + rng.below(5);
            let s = ChunkStore::with_shards(cap * one, 1);
            for i in 0..20u64 {
                s.insert(mk_chunk(i, 8));
                if rng.chance(0.3) {
                    let _ = s.get(rng.below(i as usize + 1) as u64);
                }
            }
            prop::assert_prop(
                s.stats().bytes <= cap * one,
                format!("store exceeded budget: {} > {}", s.stats().bytes, cap * one),
            )
        });
    }

    #[test]
    fn admit_consumes_stale_spill_file_and_counts_restores() {
        let dir = std::env::temp_dir()
            .join(format!("ifkv_store_admit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tier = Arc::new(SpillTier::new(&dir).unwrap());
        let s = ChunkStore::with_spill(usize::MAX, 1, tier.clone());
        // A previous process left chunk 7 spilled on disk...
        tier.spill(&mk_chunk(7, 8)).unwrap();
        assert!(tier.contains(7));
        // ...and a bulk restore admits the same id: the resident copy must
        // win and the file must go, keeping resident-xor-spilled intact.
        let arc = s.admit(mk_chunk(7, 8));
        assert_eq!(arc.id, 7);
        assert!(s.contains(7));
        assert!(!tier.contains(7), "admit must consume the stale spill file");
        assert_eq!(s.lifecycle().restores.load(Ordering::Relaxed), 1);
        // Admitting an already-resident id is a no-op returning the
        // existing entry, not a second restore.
        let again = s.admit(mk_chunk(7, 8));
        assert!(Arc::ptr_eq(&arc, &again));
        assert_eq!(s.lifecycle().restores.load(Ordering::Relaxed), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_from_streams_through_the_lifecycle_path() {
        let dir = std::env::temp_dir()
            .join(format!("ifkv_store_restore_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("chunks.bin");
        let saved = ChunkStore::new(usize::MAX);
        saved.insert(mk_chunk(7, 4));
        saved.insert(mk_chunk(9, 4));
        saved.save(&path).unwrap();

        // Restore into a LIVE store with a spill tier already holding one
        // of the ids: the restore must compose (file consumed, both ids
        // resident exactly once, nothing resident-and-spilled).
        let tier = Arc::new(SpillTier::new(dir.join("spill")).unwrap());
        let live = ChunkStore::with_spill(usize::MAX, 2, tier.clone());
        tier.spill(&mk_chunk(9, 4)).unwrap();
        let n = live.restore_from(&path).unwrap();
        assert_eq!(n, 2);
        assert_eq!(live.len(), 2);
        assert!(live.contains(7) && live.contains(9));
        assert!(!tier.contains(9), "restored id must not stay spilled");
        assert_eq!(live.lifecycle().restores.load(Ordering::Relaxed), 2);
        assert_eq!(
            live.lifecycle().duplicate_prefills.load(Ordering::Relaxed),
            0,
            "restores must never count as duplicate prefills"
        );
        // restoring again over the now-resident ids is a clean no-op
        assert_eq!(live.restore_from(&path).unwrap(), 2);
        assert_eq!(live.len(), 2);
        assert_eq!(live.lifecycle().restores.load(Ordering::Relaxed), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn with_shards_distributes_the_remainder_instead_of_dropping_it() {
        // Regression: `per_shard = budget / n` silently dropped up to n-1
        // bytes.  With budget `2*one - 1` over 2 shards the old split gave
        // every shard `one - 1` bytes — NO shard could hold a chunk, so
        // every insert thrashed.  The exact split gives the first shard
        // `one` bytes, which must retain a resident chunk.
        let one = mk_chunk(0, 8).nbytes();
        let s = ChunkStore::with_shards(2 * one - 1, 2);
        assert_eq!(
            s.stats().budget_bytes,
            2 * one - 1,
            "per-shard budgets must sum exactly to the requested total"
        );
        for id in 0..16u64 {
            s.insert(mk_chunk(id, 8));
        }
        assert!(
            !s.is_empty(),
            "a budget that fits a chunk must keep at least one resident"
        );
        assert!(s.stats().bytes <= 2 * one - 1);
    }

    #[test]
    fn tiny_budget_clamps_shard_count_instead_of_zero_byte_shards() {
        // Regression: `budget_bytes < n_shards` yielded 0-byte shards whose
        // eviction loop discarded every insert instantly.  The constructor
        // now clamps the shard count so per-shard budgets stay non-zero.
        let s = ChunkStore::with_shards(4, 8);
        assert_eq!(s.n_shards(), 4, "8 shards over 4 bytes must clamp to 4");
        assert_eq!(s.stats().budget_bytes, 4);
        // A budget below one chunk still cannot cache anything — but it
        // must say so through the thrash counter, not silently.
        let one = mk_chunk(1, 8).nbytes();
        let s = ChunkStore::with_shards(one / 2, 1);
        s.insert(mk_chunk(1, 8));
        assert!(!s.contains(1));
        assert_eq!(s.stats().bytes, 0, "thrashed insert leaves balanced bytes");
        let dump = s.stats_json().to_string_pretty();
        assert!(dump.contains("\"thrash_evictions\": 1"), "got: {dump}");
    }

    #[test]
    fn store_pins_block_eviction_and_are_visible_in_stats() {
        let one = mk_chunk(1, 8).nbytes();
        let s = ChunkStore::with_shards(2 * one, 1);
        drop(s.insert(mk_chunk(1, 8))); // no caller Arc: only the pin holds it
        assert!(s.pin(1));
        assert!(!s.pin(99), "absent ids cannot be pinned");
        s.insert(mk_chunk(2, 8));
        s.insert(mk_chunk(3, 8)); // over budget: 2 must go, never pinned 1
        assert!(s.contains(1), "pinned entry survives eviction pressure");
        assert!(!s.contains(2));
        let st = s.stats();
        assert_eq!((st.pinned_chunks, st.pinned_bytes), (1, one));
        assert!(
            st.bytes <= st.budget_bytes,
            "pinned bytes stay inside the budget accounting"
        );
        assert!(s.unpin(1));
        assert!(!s.unpin(1), "pin/unpin must balance — no underflow");
        s.insert(mk_chunk(4, 8));
        assert!(!s.contains(1), "unpinned entry rejoins LRU order");
        assert_eq!(s.stats().pinned_chunks, 0);
    }

    #[test]
    fn reinsert_preserves_pin_count() {
        let s = ChunkStore::with_shards(usize::MAX, 1);
        s.insert(mk_chunk(5, 8));
        assert!(s.pin(5));
        // A racing prefill re-inserts the same content id; the pin must
        // carry over to the replacing entry.
        s.insert(mk_chunk(5, 8));
        assert_eq!(s.stats().pinned_chunks, 1);
        assert!(s.unpin(5));
        assert!(!s.unpin(5));
    }

    #[test]
    fn pinned_entries_never_spill_and_rejoin_the_lifecycle_on_release() {
        let dir = std::env::temp_dir()
            .join(format!("ifkv_store_unpin_spill_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let one = mk_chunk(1, 8).nbytes();
        let tier = Arc::new(SpillTier::new(&dir).unwrap());
        let s = ChunkStore::with_spill(one, 1, tier.clone());
        drop(s.insert(mk_chunk(1, 8)));
        assert!(s.pin(1));
        drop(s.insert(mk_chunk(2, 8))); // over budget; only 2 is evictable
        assert!(s.contains(1), "pinned entry survives eviction pressure");
        assert!(!tier.contains(1), "a pinned chunk is never resident AND spilled");
        assert!(tier.contains(2), "the unpinned victim spilled instead");
        assert!(s.unpin(1));
        drop(s.insert(mk_chunk(3, 8))); // now 1 is the evictable LRU
        assert!(!s.contains(1) && s.contains(3));
        assert!(tier.contains(1), "released entry rejoins the spill lifecycle");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_store_never_exceeds_total_budget() {
        prop::check(25, |rng: &mut Rng| {
            let one = mk_chunk(0, 8).nbytes();
            // Per-shard budget must hold >= 1 chunk for the bound to be
            // meaningful; total = 4 shards * cap entries each.
            let cap = 1 + rng.below(4);
            let total = 4 * cap * one;
            let s = ChunkStore::with_shards(total, 4);
            for i in 0..40u64 {
                s.insert(mk_chunk(i, 8));
            }
            prop::assert_prop(
                s.stats().bytes <= total,
                format!("sharded store exceeded budget: {} > {total}", s.stats().bytes),
            )
        });
    }
}
