//! Timing statistics + a criterion-style micro-bench runner (first-party).
//!
//! `cargo bench` targets use [`Bench`] with `harness = false`: warmup,
//! repeated timed runs, mean/median/p95 with outlier-robust reporting.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Summary {
    /// Summarize a sample set; `None` for an empty one (a zero-run bench
    /// must degrade gracefully, not abort the whole bench binary).
    pub fn from_samples(mut xs: Vec<f64>) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        Some(Summary {
            n,
            mean_s: xs.iter().sum::<f64>() / n as f64,
            median_s: percentile(&xs, 0.5),
            p95_s: percentile(&xs, 0.95),
            min_s: xs[0],
            max_s: xs[n - 1],
        })
    }

    /// Machine-readable form for `BENCH_*.json` result files (CI uploads
    /// these as artifacts, so the keys are part of the bench contract).
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::from(self.n)),
            ("mean_s", Json::from(self.mean_s)),
            ("median_s", Json::from(self.median_s)),
            ("p95_s", Json::from(self.p95_s)),
            ("min_s", Json::from(self.min_s)),
            ("max_s", Json::from(self.max_s)),
        ])
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "mean {:8.3} ms  median {:8.3} ms  p95 {:8.3} ms  (n={})",
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.p95_s * 1e3,
            self.n
        )
    }
}

/// Percentile over a sorted slice, linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Criterion-lite bench runner.
pub struct Bench {
    pub warmup: usize,
    pub runs: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, runs: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, runs: usize) -> Self {
        Bench { warmup, runs }
    }

    /// Time `f` (which should do one full unit of work per call).  `None`
    /// when configured with zero runs (nothing measured, nothing printed
    /// but a note) — previously this panicked inside `from_samples`.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Option<Summary> {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        match Summary::from_samples(samples) {
            Some(s) => {
                println!("bench {name:<44} {}", s.fmt_ms());
                Some(s)
            }
            None => {
                println!("bench {name:<44} (0 runs, nothing measured)");
                None
            }
        }
    }
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_orders() {
        let s = Summary::from_samples(vec![3.0, 1.0, 2.0]).unwrap();
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
        assert_eq!(s.median_s, 2.0);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_samples_are_none_not_a_panic() {
        assert!(Summary::from_samples(vec![]).is_none());
        // regression: Bench::new(_, 0).run(..) used to abort
        let out = Bench::new(0, 0).run("noop", || 1 + 1);
        assert!(out.is_none());
    }

    #[test]
    fn summary_json_round_trips() {
        let s = Summary::from_samples(vec![3.0, 1.0, 2.0]).unwrap();
        let j = Json::parse(&s.json().to_string_compact()).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("median_s").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 1.0, 2.0, 3.0];
        assert!((percentile(&xs, 0.5) - 1.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!(s > 0.0);
    }
}
