//! Tiny CLI argument parser (first-party; offline build).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]). `flag_names` lists options that take
    /// no value; everything else starting with `--` consumes one.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number: {e}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(
            &s(&["bench", "table1", "--samples", "40", "--fast", "--out=x.json"]),
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["bench", "table1"]);
        assert_eq!(a.usize_or("samples", 0).unwrap(), 40);
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&s(&["--samples"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&s(&[]), &[]).unwrap();
        assert_eq!(a.usize_or("k", 7).unwrap(), 7);
        assert_eq!(a.get_or("m", "dflt"), "dflt");
        assert!(!a.flag("x"));
    }
}
