//! Session table: multi-turn sessions with store-accounted chunk pins and a
//! cached prep context (the paper's interactive / multi-query amortization
//! setting).
//!
//! A session owns three things:
//!
//! 1. **Pins** — ref-counted pin marks on the shared [`ChunkStore`], NOT
//!    private `Arc`s.  The store's shard budget therefore accounts pinned
//!    bytes inside `bytes`/`budget_bytes` (a pinned chunk can never be
//!    resident-AND-spilled), and N sessions pinning one viral document share
//!    a single resident copy.  The session records only `id → nbytes` so it
//!    can report `pinned_bytes` and balance every `pin` with one `unpin`.
//! 2. **A prepared context** — the previous turn's post-stage assembly
//!    buffer ([`PreparedContext`]), keyed by a fingerprint of (chunk ids,
//!    plan).  A follow-up turn with a matching fingerprint skips the prep
//!    stages entirely ([`crate::pipeline::Pipeline::begin_from_prepared`]).
//! 3. **Liveness** — a last-activity stamp.  Clients that vanish without
//!    `close` are reaped by [`SessionTable::sweep_expired`] on the router
//!    tick, which releases their pins back to LRU.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::kvcache::{ChunkId, ChunkStore};
use crate::pipeline::PreparedContext;

pub struct Session {
    /// Store-pinned chunks: id → nbytes at pin time (for reporting; the
    /// authoritative pin count lives in the store's shard entries).
    pinned: HashMap<ChunkId, usize>,
    pub queries_served: u64,
    /// Sticky worker index assigned at open — the router routes every turn
    /// of this session to the same worker so its scheduler/pool state stays
    /// warm.
    pub worker: usize,
    /// Stamped by [`Session::touch`] on every request; input to the
    /// idle-TTL sweep.
    pub last_activity: Instant,
    /// Cached post-prep context from the latest turn (None until a chunked
    /// turn completes prep, or after retrieval changes).
    pub prepared: Option<PreparedContext>,
}

impl Session {
    pub fn new(worker: usize) -> Session {
        Session {
            pinned: HashMap::new(),
            queries_served: 0,
            worker,
            last_activity: Instant::now(),
            prepared: None,
        }
    }

    pub fn touch(&mut self) {
        self.last_activity = Instant::now();
    }

    /// Pin `id` in the store on this session's behalf.  Idempotent per
    /// session (a session holds at most one pin per chunk); returns whether
    /// the chunk was resident to pin.  Callers should pin while still
    /// holding the `Arc` from `get_or_load`, so the entry cannot be evicted
    /// between lookup and pin.
    pub fn pin(&mut self, store: &ChunkStore, id: ChunkId, nbytes: usize) -> bool {
        if self.pinned.contains_key(&id) {
            return true;
        }
        if store.pin(id) {
            self.pinned.insert(id, nbytes);
            true
        } else {
            false
        }
    }

    /// Record-only half of a repin: re-point this session's bookkeeping at
    /// `keep` and return `(fresh, stale)` — ids the caller must now
    /// `store.pin` resp. `store.unpin`.  Split from the store calls so the
    /// server can run the (potentially spilling, hence blocking) store side
    /// AFTER dropping the `sessions` lock.
    pub fn swap_pins(&mut self, keep: &[(ChunkId, usize)]) -> (Vec<ChunkId>, Vec<ChunkId>) {
        let wanted: HashMap<ChunkId, usize> = keep.iter().copied().collect();
        let stale: Vec<ChunkId> =
            self.pinned.keys().copied().filter(|id| !wanted.contains_key(id)).collect();
        for id in &stale {
            self.pinned.remove(id);
        }
        let mut fresh = Vec::new();
        for (&id, &nb) in &wanted {
            if self.pinned.insert(id, nb).is_none() {
                fresh.push(id);
            }
        }
        (fresh, stale)
    }

    /// Roll back bookkeeping for pins that failed at the store (the chunk
    /// was evicted between retrieval and pin).
    pub fn forget_pins(&mut self, ids: &[ChunkId]) {
        for id in ids {
            self.pinned.remove(id);
        }
    }

    /// Re-point this session's pins at `keep`: unpin everything not in the
    /// new set, pin what is newly retrieved.  Returns how many pins the
    /// session holds afterwards.  Convenience wrapper over
    /// [`Session::swap_pins`] for callers that are not holding a lock.
    pub fn repin(&mut self, store: &ChunkStore, keep: &[(ChunkId, usize)]) -> usize {
        let (fresh, stale) = self.swap_pins(keep);
        let mut failed = Vec::new();
        for id in fresh {
            if !store.pin(id) {
                failed.push(id);
            }
        }
        for id in stale {
            store.unpin(id);
        }
        self.forget_pins(&failed);
        self.pinned.len()
    }

    /// Release every pin back to the store's LRU (close / expiry path).
    pub fn release_pins(&mut self, store: &ChunkStore) {
        for (id, _) in self.pinned.drain() {
            store.unpin(id);
        }
    }

    pub fn pinned_ids(&self) -> Vec<ChunkId> {
        self.pinned.keys().copied().collect()
    }

    pub fn pinned_bytes(&self) -> usize {
        self.pinned.values().sum()
    }
}

/// Registry of live sessions.  Shared behind a mutex named `sessions` in the
/// server (lock class `session` — see CONTRIBUTING's lock-order table); all
/// methods are plain `&mut self` so lock scopes stay in the caller's hands.
#[derive(Default)]
pub struct SessionTable {
    sessions: HashMap<u64, Session>,
    next_id: u64,
    /// Round-robin cursor for [`SessionTable::open_sticky`] affinity.
    next_worker: usize,
}

impl SessionTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a session with sticky affinity to `worker`.
    pub fn open_on(&mut self, worker: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.insert(id, Session::new(worker));
        id
    }

    /// Open with no particular affinity (worker 0).
    pub fn open(&mut self) -> u64 {
        self.open_on(0)
    }

    /// Open with round-robin affinity over `n_sticky` sticky workers
    /// (worker 0 when there are none).
    pub fn open_sticky(&mut self, n_sticky: usize) -> u64 {
        let worker = if n_sticky == 0 {
            0
        } else {
            let w = self.next_worker % n_sticky;
            self.next_worker = self.next_worker.wrapping_add(1);
            w
        };
        self.open_on(worker)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Session> {
        self.sessions.get_mut(&id)
    }

    pub fn get(&self, id: u64) -> Option<&Session> {
        self.sessions.get(&id)
    }

    /// Remove a session from the table WITHOUT touching the store — the
    /// caller releases its pins after dropping the table lock.
    pub fn remove(&mut self, id: u64) -> Option<Session> {
        self.sessions.remove(&id)
    }

    /// Detach every session idle longer than `ttl` — pins are still held;
    /// the caller releases them after dropping the table lock.
    pub fn take_expired(&mut self, ttl: Duration) -> Vec<(u64, Session)> {
        let dead: Vec<u64> = self
            .sessions
            .iter()
            .filter(|(_, s)| s.last_activity.elapsed() > ttl)
            .map(|(&id, _)| id)
            .collect();
        dead.into_iter()
            .filter_map(|id| self.sessions.remove(&id).map(|s| (id, s)))
            .collect()
    }

    /// Close a session, releasing its pins to LRU.  False if unknown.
    /// Lock-free convenience wrapper over [`SessionTable::remove`].
    pub fn close(&mut self, id: u64, store: &ChunkStore) -> bool {
        match self.remove(id) {
            Some(mut s) => {
                s.release_pins(store);
                true
            }
            None => false,
        }
    }

    /// Reap sessions idle longer than `ttl`, releasing their pins.  Returns
    /// how many expired.  Lock-free convenience wrapper over
    /// [`SessionTable::take_expired`].
    pub fn sweep_expired(&mut self, store: &ChunkStore, ttl: Duration) -> u64 {
        let expired = self.take_expired(ttl);
        let n = expired.len() as u64;
        for (_, mut s) in expired {
            s.release_pins(store);
        }
        n
    }

    /// Total bytes pinned across live sessions (metrics).
    pub fn pinned_bytes(&self) -> usize {
        self.sessions.values().map(|s| s.pinned_bytes()).sum()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::ChunkKv;
    use crate::tensor::TensorF;

    fn chunk(id: u64) -> ChunkKv {
        ChunkKv {
            id,
            tokens: vec![1, 2],
            k: TensorF::zeros(&[1, 2, 1, 2]),
            v: TensorF::zeros(&[1, 2, 1, 2]),
            key_domain: crate::kvcache::KeyDomain::Unrotated,
        }
    }

    fn one() -> usize {
        chunk(0).nbytes()
    }

    #[test]
    fn lifecycle() {
        let store = ChunkStore::new(1 << 20);
        let c = store.insert(chunk(5));
        let mut t = SessionTable::new();
        let a = t.open_on(1);
        let b = t.open();
        assert_ne!(a, b);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(a).unwrap().worker, 1);
        let s = t.get_mut(a).unwrap();
        assert!(s.pin(&store, c.id, c.nbytes()));
        assert!(s.pin(&store, c.id, c.nbytes()), "re-pin is idempotent");
        s.queries_served += 1;
        assert_eq!(s.pinned_ids(), vec![5]);
        assert_eq!(s.pinned_bytes(), c.nbytes());
        assert_eq!(t.pinned_bytes(), c.nbytes());
        assert_eq!(store.stats().pinned_chunks, 1, "one store pin despite re-pin");
        assert!(t.close(a, &store));
        assert!(!t.close(a, &store));
        assert_eq!(store.stats().pinned_chunks, 0, "close releases the pin");
        assert_eq!(t.len(), 1);

        let mut t2 = SessionTable::new();
        let assigned: Vec<usize> = (0..4)
            .map(|_| {
                let id = t2.open_sticky(3);
                t2.get(id).unwrap().worker
            })
            .collect();
        assert_eq!(assigned, vec![0, 1, 2, 0], "sticky affinity round-robins");
        let id = t2.open_sticky(0);
        assert_eq!(t2.get(id).unwrap().worker, 0, "no sticky workers => 0");
    }

    #[test]
    fn pin_of_nonresident_chunk_is_refused() {
        let store = ChunkStore::new(1 << 20);
        let mut t = SessionTable::new();
        let s = t.open();
        assert!(!t.get_mut(s).unwrap().pin(&store, 77, 1056));
        assert_eq!(t.get_mut(s).unwrap().pinned_bytes(), 0);
    }

    #[test]
    fn repin_diffs_against_the_previous_turn() {
        let store = ChunkStore::new(1 << 20);
        let a = store.insert(chunk(1));
        let b = store.insert(chunk(2));
        let c = store.insert(chunk(3));
        let mut t = SessionTable::new();
        let sid = t.open();
        let s = t.get_mut(sid).unwrap();
        assert_eq!(s.repin(&store, &[(a.id, a.nbytes()), (b.id, b.nbytes())]), 2);
        assert_eq!(store.stats().pinned_chunks, 2);
        // turn 2 keeps b, drops a, adds c
        assert_eq!(s.repin(&store, &[(b.id, b.nbytes()), (c.id, c.nbytes())]), 2);
        let mut ids = s.pinned_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3]);
        assert_eq!(store.stats().pinned_chunks, 2, "a's pin was released");
    }

    #[test]
    fn expired_session_releases_pins_to_lru() {
        // Budget fits exactly one chunk: while the session pin is live the
        // pinned chunk survives eviction pressure; once the TTL sweep reaps
        // the session, the next insert evicts it.
        let store = ChunkStore::with_shards(one(), 1);
        let c = store.insert(chunk(1));
        let mut t = SessionTable::new();
        let sid = t.open();
        assert!(t.get_mut(sid).unwrap().pin(&store, c.id, c.nbytes()));
        drop(c);
        store.insert(chunk(2)); // over budget, but 1 is pinned → 2 self-evicts
        assert!(store.contains(1), "pinned chunk survives pressure");

        // a fresh request keeps the session alive across a sweep
        t.get_mut(sid).unwrap().touch();
        assert_eq!(t.sweep_expired(&store, Duration::from_secs(3600)), 0);
        assert_eq!(t.len(), 1);

        // idle past the TTL: reaped, pin released, LRU can evict again
        assert_eq!(t.sweep_expired(&store, Duration::ZERO), 1);
        assert!(t.is_empty());
        assert_eq!(store.stats().pinned_chunks, 0);
        store.insert(chunk(3));
        assert!(!store.contains(1), "expired session's pin no longer blocks LRU");
        assert!(store.contains(3));
    }
}
