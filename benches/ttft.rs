//! End-to-end TTFT bench (criterion-lite, harness = false): measures the
//! prepared-context latency of every inference strategy at each context
//! bucket — the measured substrate behind Fig. 2 and Table 5 calibration.

use std::path::Path;
use std::sync::Arc;

use infoflow_kv::config::MethodSpec;
use infoflow_kv::kvcache::{counters, ChunkStore};
use infoflow_kv::pipeline::Pipeline;
use infoflow_kv::runtime::exec::ModelSession;
use infoflow_kv::runtime::Runtime;
use infoflow_kv::util::rng::Rng;
use infoflow_kv::util::stats::Bench;
use infoflow_kv::workload::EpisodeGen;

fn main() -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::load(Path::new("artifacts"))?);
    let backbone = rt
        .backbone_names()
        .first()
        .cloned()
        .expect("run `make artifacts` first");
    let pipeline = Pipeline::new(ModelSession::new(rt.clone(), &backbone)?)?;
    let genr = EpisodeGen::new(pipeline.vocab.clone(), rt.manifest.model.chunk);
    let bench = Bench::new(2, 8);

    for &n_chunks in &[2usize, 4, 8] {
        let mut rng = Rng::new(11);
        let e = genr.onehop(&mut rng, n_chunks);
        let store = ChunkStore::new(1 << 30);
        let (chunks, _) = pipeline.prepare_chunks(&store, &e.chunks)?;
        for (name, method) in [
            ("baseline", MethodSpec::Baseline),
            ("norecompute", MethodSpec::NoRecompute),
            ("ours16", MethodSpec::ours(16)),
            ("reorder16", MethodSpec::ours_reorder(16)),
            ("cacheblend16", MethodSpec::CacheBlend { budget: 16 }),
            ("epic16", MethodSpec::Epic { budget: 16 }),
        ] {
            let _ = bench.run(&format!("ttft/{}chunks/{name}", n_chunks), || {
                pipeline.answer(&chunks, &e.prompt, method).unwrap()
            });
            // Steady-state copy accounting for one more warm query: the
            // assemble-once + resident-decode contract in hard numbers.
            let before = counters::snapshot();
            let r = pipeline.answer(&chunks, &e.prompt, method).unwrap();
            let delta = counters::snapshot().since(&before);
            println!(
                "      {name}: {} full KV copies, {} full decode uploads, \
                 {} row updates ({} tokens)",
                delta.full_kv_copies,
                delta.decode_uploads_full,
                delta.decode_row_updates,
                r.answer.len()
            );
        }
    }
    Ok(())
}
