//! Per-query KV assembly: padded context buffers for a bucket, in-place row
//! patching with recomputed KV states, the metadata-only §4.3 chunk reorder,
//! and the decode buffer (context + prompt + generated rows).
//!
//! The serving path assembles each query's chunks ONCE into a pooled
//! [`AssembledContext`] (see [`super::pool::BufferPool`]), reorders it by
//! mutating only its [`PositionMap`] (O(chunks), zero byte movement),
//! patches the same buffer in place, and then hands it to the resident
//! decode state (`runtime::resident`) — one full-context copy per query.
//! [`DecodeBuffer`] remains as the fresh-allocation host-side reference
//! implementation that the equivalence property tests diff against.
//!
//! **Deferred RoPE.** Context key rows are stored POSITION-FREE (the
//! `unrotated` domain): raw, unrotated, unquantized.  The rotary embedding
//! is applied only at the attention boundary — the stub mini-attention and
//! the [`DecodeBuffer`] / `ResidentDecodeKv` build seam — via
//! [`crate::rope::materialize_row`], using each row's storage position from
//! `gpos`.  Because no byte of the buffer encodes its position, the §4.3
//! reorder no longer has to move bytes at all: [`AssembledContext::
//! reorder_chunks`] permutes the logical order vector and nothing else.
//! The old physical permutation survives only as
//! [`AssembledContext::eager_permute_chunks_in_place`], the reference the
//! equivalence properties and the `kv_copy` bench diff against.
//!
//! Every full-context copy and allocation is recorded in
//! [`super::counters`] so tests can assert the copy budget instead of
//! trusting comments.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::kvcache::counters;
use crate::kvcache::store::ChunkKv;
use crate::manifest::ModelDims;
use crate::rope;
use crate::tensor::{TensorF, TensorI};

/// The logical chunk order of an assembled context, kept SEPARATE from the
/// physical buffer: logical chunk slot `j` is served by the storage-order
/// chunk `order[j]`.  A §4.3 reorder mutates this vector and nothing else,
/// which is what makes the reorder O(chunks) instead of O(bytes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PositionMap {
    order: Vec<usize>,
}

impl PositionMap {
    pub fn identity(n: usize) -> PositionMap {
        PositionMap { order: (0..n).collect() }
    }

    /// `order()[j]` = index (in storage order) of the chunk serving logical
    /// slot `j`.
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn is_identity(&self) -> bool {
        self.order.iter().enumerate().all(|(i, &o)| i == o)
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Compose a further logical permutation onto the map: afterwards
    /// logical slot `j` is served by what was logical slot `perm[j]` —
    /// exactly the semantics the physical permutation had, minus the bytes.
    pub fn apply(&mut self, perm: &[usize]) -> Result<()> {
        let n = self.order.len();
        if perm.len() != n {
            bail!("permutation of {} entries for {n} chunks", perm.len());
        }
        let mut seen = vec![false; n];
        for &o in perm {
            if o >= n || seen[o] {
                bail!("order {perm:?} is not a permutation of 0..{n}");
            }
            seen[o] = true;
        }
        self.order = perm.iter().map(|&p| self.order[p]).collect();
        Ok(())
    }
}

/// A retrieved context assembled for one query: chunk KVs concatenated in
/// STORAGE order and padded to the bucket size, plus the [`PositionMap`]
/// giving the logical (post-reorder) chunk order.  `gpos` starts at the
/// *stored* (chunk-local) positions — the decode-time truth for
/// non-recomputed rows, and the seam's materialization position — and is
/// updated as recomputed rows are patched in at global positions.
pub struct AssembledContext {
    pub bucket: usize,
    /// Per-chunk lengths in STORAGE order (see [`AssembledContext::
    /// logical_chunk_lens`] for the reordered view).
    pub chunk_lens: Vec<usize>,
    pub tokens: TensorI, // [bucket]
    /// Position-free key rows: raw and unrotated, exactly as the chunk
    /// store holds them.  Rotation happens at the attention seam.
    // lint:domain(unrotated)
    pub k: TensorF,      // [L, bucket, H, Dh]
    pub v: TensorF,      // [L, bucket, H, Dh]
    // `gpos` carries no position-domain seed on purpose: it is mixed-domain
    // by design (chunk-local until `patch` writes global positions over the
    // recomputed rows), so neither `local` nor `global` would be truthful.
    // It is also the seam's storage-position input: row r's key materializes
    // at `gpos[r]`.
    pub gpos: TensorI,   // [bucket] decode-phase positions
    pub valid: TensorF,  // [bucket]
    /// Logical chunk order; identity right after assembly.
    pub pos_map: PositionMap,
    dims: (usize, usize, usize),
}

/// Permute equal-size blocks of `data` in place so that the block at index
/// `i` afterwards holds the block that was at `order[i]`.  One save/restore
/// per cycle; every block is written exactly once.  `bases` gives the start
/// offset of each independent block region (one per layer for KV buffers).
fn permute_equal_blocks<T: Copy>(
    data: &mut [T],
    bases: &[usize],
    block: usize,
    order: &[usize],
) {
    let k = order.len();
    let mut tmp: Vec<T> = Vec::with_capacity(block);
    let mut done = vec![false; k];
    for &base in bases {
        done.fill(false);
        for start in 0..k {
            if done[start] || order[start] == start {
                done[start] = true;
                continue;
            }
            tmp.clear();
            tmp.extend_from_slice(&data[base + start * block..base + (start + 1) * block]);
            let mut dst = start;
            loop {
                let src = order[dst];
                done[dst] = true;
                if src == start {
                    data[base + dst * block..base + (dst + 1) * block]
                        .copy_from_slice(&tmp);
                    break;
                }
                data.copy_within(
                    base + src * block..base + (src + 1) * block,
                    base + dst * block,
                );
                dst = src;
            }
        }
    }
}

impl AssembledContext {
    /// A zeroed, unassembled buffer for `bucket` context rows — the unit a
    /// [`super::pool::BufferPool`] recycles.
    pub fn alloc(dims: &ModelDims, bucket: usize) -> Self {
        let (l, h, dh) = (dims.n_layers, dims.n_heads, dims.head_dim);
        counters::bump(|s| s.ctx_allocs += 1);
        AssembledContext {
            bucket,
            chunk_lens: Vec::new(),
            tokens: TensorI::zeros(&[bucket]),
            k: TensorF::zeros(&[l, bucket, h, dh]),
            v: TensorF::zeros(&[l, bucket, h, dh]),
            gpos: TensorI::zeros(&[bucket]),
            valid: TensorF::zeros(&[bucket]),
            pos_map: PositionMap::identity(0),
            dims: (l, h, dh),
        }
    }

    /// Whether this buffer can be reused for (`dims`, `bucket`).
    pub fn matches(&self, dims: &ModelDims, bucket: usize) -> bool {
        self.bucket == bucket
            && self.dims == (dims.n_layers, dims.n_heads, dims.head_dim)
    }

    pub fn new(dims: &ModelDims, bucket: usize, chunks: &[Arc<ChunkKv>]) -> Result<Self> {
        let mut ctx = AssembledContext::alloc(dims, bucket);
        ctx.assemble_into(chunks)?;
        Ok(ctx)
    }

    /// (Re)assemble `chunks` into this buffer, overwriting whatever query
    /// used it before.  Rows `[0, n)` are fully rewritten from the chunks;
    /// rows `[n, bucket)` are zeroed so a recycled buffer is bit-identical
    /// to a freshly allocated one.  This is the ONE full-context copy the
    /// steady-state query path performs.
    pub fn assemble_into(&mut self, chunks: &[Arc<ChunkKv>]) -> Result<()> {
        let (l, h, dh) = self.dims;
        let bucket = self.bucket;
        let n: usize = chunks.iter().map(|c| c.len()).sum();
        if n > bucket {
            bail!("context of {n} tokens does not fit bucket {bucket}");
        }
        counters::bump(|s| {
            s.ctx_assembles += 1;
            s.full_kv_copies += 1;
        });
        let row = h * dh;
        // metadata: real rows from the chunks, stale padding rows cleared
        let mut at = 0usize;
        for c in chunks {
            for t in 0..c.len() {
                self.tokens.data_mut()[at + t] = c.tokens[t];
                self.gpos.data_mut()[at + t] = t as i32; // stored chunk-local
                self.valid.data_mut()[at + t] = 1.0;
            }
            at += c.len();
        }
        self.tokens.data_mut()[n..bucket].fill(0);
        self.gpos.data_mut()[n..bucket].fill(0);
        self.valid.data_mut()[n..bucket].fill(0.0);
        // KV rows: copy the chunk blocks, zero the stale padding region
        for li in 0..l {
            let mut at = 0usize;
            for c in chunks {
                let clen = c.len();
                let src = (li * clen) * row;
                let dst = (li * bucket + at) * row;
                self.k.data_mut()[dst..dst + clen * row]
                    .copy_from_slice(&c.k.data()[src..src + clen * row]);
                self.v.data_mut()[dst..dst + clen * row]
                    .copy_from_slice(&c.v.data()[src..src + clen * row]);
                at += clen;
            }
            let pad = (li * bucket + n) * row;
            let end = (li + 1) * bucket * row;
            self.k.data_mut()[pad..end].fill(0.0);
            self.v.data_mut()[pad..end].fill(0.0);
        }
        self.chunk_lens = chunks.iter().map(|c| c.len()).collect();
        self.pos_map = PositionMap::identity(chunks.len());
        Ok(())
    }

    /// Number of real (non-padding) context rows.
    pub fn n(&self) -> usize {
        self.chunk_lens.iter().sum()
    }

    /// Chunk lengths in LOGICAL (post-reorder) order — what the positional
    /// geometry layouts consume.
    pub fn logical_chunk_lens(&self) -> Vec<usize> {
        self.pos_map
            .order()
            .iter()
            .map(|&s| self.chunk_lens[s])
            .collect()
    }

    /// Row-level logical→physical map: entry `j` is the storage row holding
    /// the row that is logically `j`-th.  Padding rows `[n, bucket)` map to
    /// themselves.  This is the gather order the attention seams walk, and
    /// the `order` operand handed to the executables.
    pub fn logical_row_order(&self) -> Vec<i32> {
        let mut offsets = Vec::with_capacity(self.chunk_lens.len());
        let mut acc = 0usize;
        for &len in &self.chunk_lens {
            offsets.push(acc);
            acc += len;
        }
        let mut out = Vec::with_capacity(self.bucket);
        for &s in self.pos_map.order() {
            let base = offsets[s];
            out.extend((base..base + self.chunk_lens[s]).map(|r| r as i32));
        }
        out.extend((out.len()..self.bucket).map(|r| r as i32));
        out
    }

    /// Approximate heap footprint of the buffers, for session accounting.
    pub fn nbytes(&self) -> usize {
        (self.k.data().len() + self.v.data().len() + self.valid.data().len()) * 4
            + (self.tokens.data().len() + self.gpos.data().len()) * 4
    }

    /// An owned copy of this buffer for retention beyond the pool checkout
    /// (session prep reuse).  This is a deliberate full-context copy and
    /// allocation, counted as both so the hot-path budget stays honest —
    /// it is paid once per session turn that opts into caching, not per
    /// query.
    pub fn snapshot(&self) -> Self {
        counters::bump(|s| {
            s.ctx_allocs += 1;
            s.full_kv_copies += 1;
        });
        AssembledContext {
            bucket: self.bucket,
            chunk_lens: self.chunk_lens.clone(),
            tokens: self.tokens.clone(),
            k: self.k.clone(),
            v: self.v.clone(),
            gpos: self.gpos.clone(),
            valid: self.valid.clone(),
            pos_map: self.pos_map.clone(),
            dims: self.dims,
        }
    }

    /// The §4.3 reorder, metadata-only: afterwards LOGICAL chunk slot `i`
    /// is served by what was logical chunk `order[i]` — exactly the layout
    /// a physical permutation (or a reassembly from the permuted chunk
    /// list) would have produced, but achieved by mutating the
    /// [`PositionMap`] alone.  O(chunks) work, ZERO context bytes moved;
    /// possible because stored key rows are position-free, so no byte of
    /// the buffer depends on where its chunk sits in the logical order.
    pub fn reorder_chunks(&mut self, order: &[usize]) -> Result<()> {
        if order.len() != self.chunk_lens.len() {
            bail!(
                "permutation of {} entries for {} chunks",
                order.len(),
                self.chunk_lens.len()
            );
        }
        if order.iter().enumerate().all(|(i, &o)| i == o) {
            return Ok(());
        }
        self.pos_map.apply(order)?;
        counters::bump(|s| s.meta_reorders += 1);
        Ok(())
    }

    /// REFERENCE implementation of the §4.3 reorder: physically permute the
    /// assembled chunk blocks so storage order equals logical order.  Kept
    /// only for the equivalence property tests and the `kv_copy` bench to
    /// diff [`AssembledContext::reorder_chunks`] against; the serving path
    /// never calls it.  Supports equal-length chunks only (the variable-
    /// length gather fallback is gone — the metadata reorder handles any
    /// mix of lengths for free) and requires an identity [`PositionMap`]
    /// (mixing physical and metadata reorders on one buffer would double-
    /// apply the permutation).
    pub fn eager_permute_chunks_in_place(&mut self, order: &[usize]) -> Result<()> {
        let nc = self.chunk_lens.len();
        if order.len() != nc {
            bail!("permutation of {} entries for {nc} chunks", order.len());
        }
        let mut seen = vec![false; nc];
        for &o in order {
            if o >= nc || seen[o] {
                bail!("order {order:?} is not a permutation of 0..{nc}");
            }
            seen[o] = true;
        }
        if !self.pos_map.is_identity() {
            bail!("eager permutation on a metadata-reordered buffer");
        }
        if order.iter().enumerate().all(|(i, &o)| i == o) {
            return Ok(());
        }
        if self.chunk_lens.iter().any(|&c| c != self.chunk_lens[0]) {
            bail!(
                "eager permutation requires equal-length chunks (lens {:?}); \
                 use the metadata reorder",
                self.chunk_lens
            );
        }
        let (l, h, dh) = self.dims;
        let row = h * dh;
        let clen = self.chunk_lens[0];
        let kv_bases: Vec<usize> = (0..l).map(|li| li * self.bucket * row).collect();
        permute_equal_blocks(self.k.data_mut(), &kv_bases, clen * row, order);
        permute_equal_blocks(self.v.data_mut(), &kv_bases, clen * row, order);
        permute_equal_blocks(self.tokens.data_mut(), &[0], clen, order);
        permute_equal_blocks(self.gpos.data_mut(), &[0], clen, order);
        permute_equal_blocks(self.valid.data_mut(), &[0], clen, order);
        counters::bump(|s| s.inplace_permutes += 1);
        self.chunk_lens = order.iter().map(|&i| self.chunk_lens[i]).collect();
        Ok(())
    }

    /// Patch recomputed rows into the buffers: LOGICAL row `slots[i]`
    /// receives `new_k/new_v[:, i]` and its decode position becomes
    /// `sel_gpos[i]`.  Slots are logical (post-reorder) indices — the index
    /// space scores and selections live in — and are mapped through the
    /// [`PositionMap`] to storage rows here.  Slots >= bucket (padding of
    /// the selection) are skipped.  Shape mismatches are hard errors — a
    /// silent partial patch corrupts the decode cache.  `sel_gpos` must
    /// already be target-frame (global) positions — patching stored
    /// chunk-local positions here would poison the decode cache with
    /// un-re-rotated coordinates.  `new_k` rows are position-free
    /// (unrotated), like every other key row in the buffer.
    // lint:domain(global)
    pub fn patch(
        &mut self,
        slots: &[i32],
        sel_gpos: &[i32],
        count: usize,
        new_k: &TensorF, // [L, S, H, Dh]
        new_v: &TensorF,
    ) -> Result<()> {
        let (l, h, dh) = self.dims;
        let row = h * dh;
        if new_k.shape().len() != 4
            || new_k.shape()[0] != l
            || new_k.shape()[2] != h
            || new_k.shape()[3] != dh
        {
            bail!(
                "patch: new_k shape {:?} does not match [L={l}, S, H={h}, Dh={dh}]",
                new_k.shape()
            );
        }
        if new_v.shape() != new_k.shape() {
            bail!(
                "patch: new_v shape {:?} != new_k shape {:?}",
                new_v.shape(),
                new_k.shape()
            );
        }
        let s_cap = new_k.shape()[1];
        if count > s_cap || count > slots.len() || count > sel_gpos.len() {
            bail!(
                "patch: count {count} exceeds capacity (S={s_cap}, slots={}, gpos={})",
                slots.len(),
                sel_gpos.len()
            );
        }
        let lro = self.logical_row_order();
        for (i, (&slot, &gp)) in slots.iter().zip(sel_gpos).take(count).enumerate() {
            let slot = slot as usize;
            if slot >= self.bucket {
                continue;
            }
            let phys = lro[slot] as usize;
            for li in 0..l {
                let src = (li * s_cap + i) * row;
                let dst = (li * self.bucket + phys) * row;
                self.k.data_mut()[dst..dst + row]
                    .copy_from_slice(&new_k.data()[src..src + row]);
                self.v.data_mut()[dst..dst + row]
                    .copy_from_slice(&new_v.data()[src..src + row]);
            }
            self.gpos.data_mut()[phys] = gp;
        }
        Ok(())
    }
}

/// The decode-phase KV buffer: [L, T, H, Dh] with T = bucket + prompt + answer
/// slots.  Context rows come from an [`AssembledContext`], prompt rows from
/// the score executable, generated rows are appended per decode step.
///
/// This is the fresh-allocation HOST-SIDE REFERENCE path.  Production
/// decoding uses `runtime::resident::ResidentDecodeKv`, which keeps the same
/// layout inside a reusable literal and updates it row-by-row; the
/// equivalence property tests diff the two bit-for-bit.
pub struct DecodeBuffer {
    pub k: TensorF,     // [L, T, H, Dh]
    pub v: TensorF,     // [L, T, H, Dh]
    pub gpos: TensorI,  // [T]
    pub valid: TensorF, // [T]
    pub next_row: usize,
    pub next_pos: i32,
    dims: (usize, usize, usize),
}

impl DecodeBuffer {
    /// Build the decode buffer from an assembled context.  This is one of
    /// the two attention seams of the deferred-RoPE design: context rows are
    /// gathered in LOGICAL order (through the context's [`PositionMap`])
    /// during the one full copy this build already pays, and each key row is
    /// converted from the position-free storage domain to the attention
    /// domain by [`rope::materialize_row`] at its storage position
    /// `ctx.gpos[r]`.  The resulting bytes are identical to what the old
    /// eager path stored (it kept `snap(rotate(raw, pos))` in the buffer and
    /// copied verbatim), so downstream decode executables are unchanged.
    pub fn new(
        dims: &ModelDims,
        ctx: &AssembledContext,
        prompt_k: &TensorF, // [L, P, H, Dh]
        prompt_v: &TensorF,
        prompt_pos: &[i32],
    ) -> DecodeBuffer {
        counters::bump(|s| s.full_kv_copies += 1);
        let (l, h, dh) = (dims.n_layers, dims.n_heads, dims.head_dim);
        let p = dims.prompt_len;
        let t_total = ctx.bucket + p + dims.answer_buf;
        let row = h * dh;
        let mut k = TensorF::zeros(&[l, t_total, h, dh]);
        let mut v = TensorF::zeros(&[l, t_total, h, dh]);
        let mut gpos = TensorI::zeros(&[t_total]);
        let mut valid = TensorF::zeros(&[t_total]);
        let lro = ctx.logical_row_order();
        for li in 0..l {
            // context rows [0, bucket): logical gather + key materialization
            for (j, &pr) in lro.iter().enumerate() {
                let r = pr as usize;
                let src = (li * ctx.bucket + r) * row;
                let dst = (li * t_total + j) * row;
                k.data_mut()[dst..dst + row]
                    .copy_from_slice(&ctx.k.data()[src..src + row]);
                rope::materialize_row(
                    &mut k.data_mut()[dst..dst + row],
                    h,
                    dh,
                    ctx.gpos.data()[r] as i64,
                    dims.rope_theta,
                );
                v.data_mut()[dst..dst + row]
                    .copy_from_slice(&ctx.v.data()[src..src + row]);
            }
            // prompt rows [bucket, bucket + p)
            let psrc = (li * p) * row;
            let pdst = (li * t_total + ctx.bucket) * row;
            k.data_mut()[pdst..pdst + p * row]
                .copy_from_slice(&prompt_k.data()[psrc..psrc + p * row]);
            v.data_mut()[pdst..pdst + p * row]
                .copy_from_slice(&prompt_v.data()[psrc..psrc + p * row]);
        }
        for (j, &pr) in lro.iter().enumerate() {
            let r = pr as usize;
            gpos.data_mut()[j] = ctx.gpos.data()[r];
            valid.data_mut()[j] = ctx.valid.data()[r];
        }
        for (i, &pp) in prompt_pos.iter().enumerate() {
            gpos.data_mut()[ctx.bucket + i] = pp;
            valid.data_mut()[ctx.bucket + i] = 1.0;
        }
        DecodeBuffer {
            k,
            v,
            gpos,
            valid,
            next_row: ctx.bucket + p,
            next_pos: prompt_pos.last().copied().unwrap_or(0) + 1,
            dims: (l, h, dh),
        }
    }

    pub fn capacity(&self) -> usize {
        self.gpos.len()
    }

    /// Build a decode buffer from an arbitrary [L, X, H, Dh] KV block (used
    /// by the full-prefill baseline, where context + prompt KV come from one
    /// executable).  Rows [0, X) are copied; `answer_buf` empty slots are
    /// appended; decoding continues from `next_pos`.  Shape mismatches are
    /// hard errors, not debug-only assertions.
    pub fn from_parts(
        dims: &ModelDims,
        k: &TensorF,
        v: &TensorF,
        gpos: &[i32],
        valid: &[f32],
        next_pos: i32,
    ) -> Result<DecodeBuffer> {
        let (l, h, dh) = (dims.n_layers, dims.n_heads, dims.head_dim);
        if k.shape().len() != 4 || k.shape()[0] != l || k.shape()[2] != h || k.shape()[3] != dh
        {
            bail!(
                "from_parts: k shape {:?} does not match [L={l}, X, H={h}, Dh={dh}]",
                k.shape()
            );
        }
        if v.shape() != k.shape() {
            bail!("from_parts: v shape {:?} != k shape {:?}", v.shape(), k.shape());
        }
        let x = k.shape()[1];
        if gpos.len() != x || valid.len() != x {
            bail!(
                "from_parts: gpos/valid lengths ({}, {}) != {x} KV rows",
                gpos.len(),
                valid.len()
            );
        }
        counters::bump(|s| s.full_kv_copies += 1);
        let t_total = x + dims.answer_buf;
        let row = h * dh;
        let mut kk = TensorF::zeros(&[l, t_total, h, dh]);
        let mut vv = TensorF::zeros(&[l, t_total, h, dh]);
        for li in 0..l {
            let src = (li * x) * row;
            let dst = (li * t_total) * row;
            kk.data_mut()[dst..dst + x * row]
                .copy_from_slice(&k.data()[src..src + x * row]);
            vv.data_mut()[dst..dst + x * row]
                .copy_from_slice(&v.data()[src..src + x * row]);
        }
        let mut g = TensorI::zeros(&[t_total]);
        let mut val = TensorF::zeros(&[t_total]);
        g.data_mut()[..x].copy_from_slice(gpos);
        val.data_mut()[..x].copy_from_slice(valid);
        Ok(DecodeBuffer {
            k: kk,
            v: vv,
            gpos: g,
            valid: val,
            next_row: x,
            next_pos,
            dims: (l, h, dh),
        })
    }

    /// Append a generated token's KV row (from a decode step).
    pub fn append(&mut self, new_k: &TensorF, new_v: &TensorF) -> Result<()> {
        let (l, h, dh) = self.dims;
        let row = h * dh;
        let t_total = self.capacity();
        if self.next_row >= t_total {
            bail!("decode buffer full ({t_total} rows)");
        }
        for li in 0..l {
            let src = li * row;
            let dst = (li * t_total + self.next_row) * row;
            self.k.data_mut()[dst..dst + row]
                .copy_from_slice(&new_k.data()[src..src + row]);
            self.v.data_mut()[dst..dst + row]
                .copy_from_slice(&new_v.data()[src..src + row]);
        }
        self.gpos.data_mut()[self.next_row] = self.next_pos;
        self.valid.data_mut()[self.next_row] = 1.0;
        self.next_row += 1;
        self.next_pos += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::store::KeyDomain;
    use crate::util::{prop, rng::Rng};

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 144,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            d_ff: 128,
            rope_theta: 10000.0,
            chunk: 8,
            prompt_len: 4,
            sel_budget: 8,
            answer_buf: 3,
            dev_layers: 2,
        }
    }

    fn chunk(id: u64, len: usize, fill: f32) -> Arc<ChunkKv> {
        let d = dims();
        let shape = [d.n_layers, len, d.n_heads, d.head_dim];
        let n: usize = shape.iter().product();
        Arc::new(ChunkKv {
            id,
            tokens: (0..len as i32).map(|t| t + id as i32 * 100).collect(),
            k: TensorF::from_vec(&shape, vec![fill; n]).unwrap(),
            v: TensorF::from_vec(&shape, vec![fill * 10.0; n]).unwrap(),
            key_domain: KeyDomain::Unrotated,
        })
    }

    /// A chunk whose KV rows are all distinct (id/layer/row/head encoded),
    /// so permutation bugs cannot cancel out.
    fn distinct_chunk(rng: &mut Rng, id: u64, len: usize) -> Arc<ChunkKv> {
        let d = dims();
        let shape = [d.n_layers, len, d.n_heads, d.head_dim];
        let n: usize = shape.iter().product();
        let kv: Vec<f32> = (0..n)
            .map(|i| id as f32 * 1000.0 + i as f32 + rng.f64() as f32)
            .collect();
        let vv: Vec<f32> = kv.iter().map(|x| -x).collect();
        Arc::new(ChunkKv {
            id,
            tokens: (0..len as i32).map(|t| t + id as i32 * 100).collect(),
            k: TensorF::from_vec(&shape, kv).unwrap(),
            v: TensorF::from_vec(&shape, vv).unwrap(),
            key_domain: KeyDomain::Unrotated,
        })
    }

    /// Logical-order view of a context's per-row data (tokens, gpos, valid,
    /// k, v) — what a downstream consumer walking the [`PositionMap`]
    /// observes, independent of physical storage order.
    fn logical_view(ctx: &AssembledContext) -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let lro = ctx.logical_row_order();
        let (l, row) = (ctx.k.shape()[0], ctx.k.shape()[2] * ctx.k.shape()[3]);
        let mut toks = Vec::new();
        let mut gpos = Vec::new();
        let mut valid = Vec::new();
        let mut k = Vec::new();
        let mut v = Vec::new();
        for &pr in &lro {
            let r = pr as usize;
            toks.push(ctx.tokens.data()[r]);
            gpos.push(ctx.gpos.data()[r]);
            valid.push(ctx.valid.data()[r]);
        }
        for li in 0..l {
            for &pr in &lro {
                let r = pr as usize;
                let s = (li * ctx.bucket + r) * row;
                k.extend_from_slice(&ctx.k.data()[s..s + row]);
                v.extend_from_slice(&ctx.v.data()[s..s + row]);
            }
        }
        (toks, gpos, valid, k, v)
    }

    fn assert_ctx_eq(a: &AssembledContext, b: &AssembledContext, what: &str) {
        assert_eq!(a.bucket, b.bucket, "{what}: bucket");
        assert_eq!(a.chunk_lens, b.chunk_lens, "{what}: chunk_lens");
        assert_eq!(a.tokens.data(), b.tokens.data(), "{what}: tokens");
        assert_eq!(a.gpos.data(), b.gpos.data(), "{what}: gpos");
        assert_eq!(a.valid.data(), b.valid.data(), "{what}: valid");
        assert_eq!(a.k.data(), b.k.data(), "{what}: k");
        assert_eq!(a.v.data(), b.v.data(), "{what}: v");
    }

    #[test]
    fn assembly_concatenates_in_order() {
        let d = dims();
        let ctx = AssembledContext::new(&d, 32, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)])
            .unwrap();
        assert_eq!(ctx.n(), 16);
        assert_eq!(ctx.tokens.data()[0], 100);
        assert_eq!(ctx.tokens.data()[8], 200);
        // stored positions are chunk-local
        assert_eq!(ctx.gpos.data()[7], 7);
        assert_eq!(ctx.gpos.data()[8], 0);
        // kv rows land in the right place for every layer
        for li in 0..d.n_layers {
            assert_eq!(ctx.k.at(&[li, 0, 0, 0]), 1.0);
            assert_eq!(ctx.k.at(&[li, 8, 0, 0]), 2.0);
            assert_eq!(ctx.v.at(&[li, 8, 1, 3]), 20.0);
            // padding rows stay zero/invalid
            assert_eq!(ctx.k.at(&[li, 16, 0, 0]), 0.0);
        }
        assert_eq!(ctx.valid.data()[15], 1.0);
        assert_eq!(ctx.valid.data()[16], 0.0);
    }

    #[test]
    fn assembly_rejects_overflow() {
        let d = dims();
        assert!(AssembledContext::new(&d, 8, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)])
            .is_err());
    }

    #[test]
    fn reused_buffer_is_bit_identical_to_fresh() {
        let d = dims();
        let mut pooled = AssembledContext::alloc(&d, 32);
        // First query dirties the buffer thoroughly: 3 chunks + a patch.
        pooled
            .assemble_into(&[chunk(1, 8, 1.0), chunk(2, 8, 2.0), chunk(3, 8, 3.0)])
            .unwrap();
        let s = 2usize;
        let shape = [d.n_layers, s, d.n_heads, d.head_dim];
        pooled
            .patch(
                &[5, 20],
                &[5, 20],
                2,
                &TensorF::full(&shape, 7.0),
                &TensorF::full(&shape, 9.0),
            )
            .unwrap();
        // Second query is SHORTER: stale rows from query 1 must not leak.
        let chunks2 = [chunk(9, 8, 4.0)];
        pooled.assemble_into(&chunks2).unwrap();
        let fresh = AssembledContext::new(&d, 32, &chunks2).unwrap();
        assert_ctx_eq(&pooled, &fresh, "reused vs fresh");
    }

    #[test]
    fn eager_permutation_matches_reassembly() {
        let d = dims();
        let mut rng = Rng::new(42);
        let chunks: Vec<_> = (0..4).map(|i| distinct_chunk(&mut rng, i, 8)).collect();
        let order = vec![2usize, 0, 3, 1];
        let mut inplace = AssembledContext::new(&d, 64, &chunks).unwrap();
        inplace.eager_permute_chunks_in_place(&order).unwrap();
        let permuted: Vec<_> = order.iter().map(|&i| chunks[i].clone()).collect();
        let reference = AssembledContext::new(&d, 64, &permuted).unwrap();
        assert_ctx_eq(&inplace, &reference, "in-place vs reassembled");
    }

    #[test]
    fn metadata_reorder_random_property() {
        // The metadata reorder must present, through its logical view,
        // exactly what reassembling from the permuted chunk list would have
        // produced physically — for ANY mix of chunk lengths (the old
        // physical gather fallback is gone; variable lengths are free now).
        let d = dims();
        prop::check(60, |rng: &mut Rng| {
            let nc = 1 + rng.below(6);
            for &mixed in &[false, true] {
                let chunks: Vec<_> = (0..nc)
                    .map(|i| {
                        let len = if mixed { 2 + rng.below(7) } else { 8 };
                        distinct_chunk(rng, i as u64, len)
                    })
                    .collect();
                let n: usize = chunks.iter().map(|c| c.len()).sum();
                let bucket = n + rng.below(9);
                // random permutation via sort-by-random-key
                let mut order: Vec<usize> = (0..nc).collect();
                let keys: Vec<u64> = (0..nc).map(|_| rng.next_u64()).collect();
                order.sort_by_key(|&i| keys[i]);
                let mut meta = AssembledContext::new(&d, bucket, &chunks).unwrap();
                meta.reorder_chunks(&order).unwrap();
                let permuted: Vec<_> = order.iter().map(|&i| chunks[i].clone()).collect();
                let reference = AssembledContext::new(&d, bucket, &permuted).unwrap();
                prop::assert_prop(
                    logical_view(&meta) == logical_view(&reference)
                        && meta.logical_chunk_lens() == reference.chunk_lens,
                    format!("reorder mismatch (mixed={mixed}, order={order:?})"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn metadata_reorder_composes_like_repeated_permutation() {
        // Two stacked reorders must equal reassembling with the composed
        // permutation — the §4.3 policy may fire more than once per buffer.
        let d = dims();
        let mut rng = Rng::new(7);
        let chunks: Vec<_> = (0..5).map(|i| distinct_chunk(&mut rng, i, 4)).collect();
        let mut meta = AssembledContext::new(&d, 32, &chunks).unwrap();
        let p1 = vec![4usize, 2, 0, 1, 3];
        let p2 = vec![1usize, 0, 4, 3, 2];
        meta.reorder_chunks(&p1).unwrap();
        meta.reorder_chunks(&p2).unwrap();
        let composed: Vec<usize> = p2.iter().map(|&j| p1[j]).collect();
        let permuted: Vec<_> = composed.iter().map(|&i| chunks[i].clone()).collect();
        let reference = AssembledContext::new(&d, 32, &permuted).unwrap();
        assert_eq!(logical_view(&meta), logical_view(&reference));
    }

    #[test]
    fn equal_chunk_permutation_is_inplace_not_a_copy() {
        let d = dims();
        let chunks: Vec<_> = (0..4).map(|i| chunk(i, 8, i as f32 + 1.0)).collect();
        let mut ctx = AssembledContext::new(&d, 32, &chunks).unwrap();
        let before = counters::snapshot();
        ctx.eager_permute_chunks_in_place(&[3, 1, 0, 2]).unwrap();
        let delta = counters::snapshot().since(&before);
        assert_eq!(delta.full_kv_copies, 0, "equal chunks must permute in place");
        assert_eq!(delta.inplace_permutes, 1);
    }

    #[test]
    fn metadata_reorder_moves_zero_bytes() {
        let d = dims();
        let mut rng = Rng::new(3);
        let chunks: Vec<_> = (0..4).map(|i| distinct_chunk(&mut rng, i, 8)).collect();
        let mut ctx = AssembledContext::new(&d, 32, &chunks).unwrap();
        let k_before = ctx.k.data().to_vec();
        let before = counters::snapshot();
        ctx.reorder_chunks(&[3, 1, 0, 2]).unwrap();
        let delta = counters::snapshot().since(&before);
        assert_eq!(delta.meta_reorders, 1);
        assert_eq!(delta.full_kv_copies, 0, "metadata reorder must not copy");
        assert_eq!(delta.ctx_allocs, 0, "metadata reorder must not allocate");
        assert_eq!(delta.inplace_permutes, 0);
        assert_eq!(ctx.k.data(), &k_before[..], "buffer bytes must be untouched");
        assert_eq!(ctx.pos_map.order(), &[3, 1, 0, 2]);
    }

    #[test]
    fn identity_reorder_is_free() {
        let d = dims();
        let mut ctx =
            AssembledContext::new(&d, 32, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)]).unwrap();
        let before = counters::snapshot();
        ctx.reorder_chunks(&[0, 1]).unwrap();
        assert_eq!(counters::snapshot().since(&before).meta_reorders, 0);
        assert!(ctx.pos_map.is_identity());
    }

    #[test]
    fn permutation_rejects_non_permutations() {
        let d = dims();
        let mut ctx =
            AssembledContext::new(&d, 32, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)]).unwrap();
        assert!(ctx.reorder_chunks(&[0]).is_err(), "wrong length");
        assert!(ctx.reorder_chunks(&[0, 0]).is_err(), "duplicate");
        assert!(ctx.reorder_chunks(&[0, 2]).is_err(), "out of range");
        assert!(ctx.eager_permute_chunks_in_place(&[0]).is_err(), "wrong length");
        assert!(ctx.eager_permute_chunks_in_place(&[0, 0]).is_err(), "duplicate");
        assert!(ctx.eager_permute_chunks_in_place(&[0, 2]).is_err(), "out of range");
        // the eager reference refuses to stack on a metadata reorder
        ctx.reorder_chunks(&[1, 0]).unwrap();
        assert!(ctx.eager_permute_chunks_in_place(&[1, 0]).is_err());
        // and refuses variable-length chunks (its gather fallback is gone)
        let mut varied =
            AssembledContext::new(&d, 32, &[chunk(1, 8, 1.0), chunk(2, 4, 2.0)]).unwrap();
        assert!(varied.eager_permute_chunks_in_place(&[1, 0]).is_err());
        assert!(varied.reorder_chunks(&[1, 0]).is_ok(), "metadata path handles it");
    }

    #[test]
    fn patch_updates_rows_and_positions() {
        let d = dims();
        let mut ctx =
            AssembledContext::new(&d, 16, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)]).unwrap();
        let s = 4usize;
        let shape = [d.n_layers, s, d.n_heads, d.head_dim];
        let nk = TensorF::full(&shape, 7.0);
        let nv = TensorF::full(&shape, 9.0);
        // patch rows 3 and 9; slot 99 (>= bucket) is selection padding
        ctx.patch(&[3, 9, 99, 99], &[3, 9, 0, 0], 2, &nk, &nv).unwrap();
        assert_eq!(ctx.k.at(&[0, 3, 0, 0]), 7.0);
        assert_eq!(ctx.v.at(&[1, 9, 1, 3]), 9.0);
        assert_eq!(ctx.gpos.data()[9], 9, "patched row gets its global position");
        // neighbours untouched
        assert_eq!(ctx.k.at(&[0, 4, 0, 0]), 1.0);
        assert_eq!(ctx.gpos.data()[10], 2);
    }

    #[test]
    fn patch_maps_logical_slots_through_the_reorder() {
        let d = dims();
        let mut ctx =
            AssembledContext::new(&d, 16, &[chunk(1, 8, 1.0), chunk(2, 8, 2.0)]).unwrap();
        ctx.reorder_chunks(&[1, 0]).unwrap();
        let s = 1usize;
        let shape = [d.n_layers, s, d.n_heads, d.head_dim];
        // logical slot 2 now lives in chunk 2, physical row 8 + 2 = 10
        ctx.patch(&[2], &[42], 1, &TensorF::full(&shape, 7.0), &TensorF::full(&shape, 9.0))
            .unwrap();
        assert_eq!(ctx.k.at(&[0, 10, 0, 0]), 7.0, "physical row of logical slot 2");
        assert_eq!(ctx.gpos.data()[10], 42);
        assert_eq!(ctx.k.at(&[0, 2, 0, 0]), 1.0, "physical row 2 untouched");
        assert_eq!(ctx.gpos.data()[2], 2);
    }

    #[test]
    fn decode_buffer_from_metadata_reorder_matches_reference() {
        // The decode-build seam must normalize a metadata-reordered buffer
        // into exactly the bytes the physically-reassembled reference
        // produces: logical gather + key materialization at storage
        // positions.
        let d = dims();
        let mut rng = Rng::new(11);
        let chunks: Vec<_> = (0..2).map(|i| distinct_chunk(&mut rng, i, 6)).collect();
        let order = vec![1usize, 0];
        let mut meta = AssembledContext::new(&d, 16, &chunks).unwrap();
        meta.reorder_chunks(&order).unwrap();
        let permuted: Vec<_> = order.iter().map(|&i| chunks[i].clone()).collect();
        let reference = AssembledContext::new(&d, 16, &permuted).unwrap();
        let p_shape = [d.n_layers, d.prompt_len, d.n_heads, d.head_dim];
        let pk = TensorF::full(&p_shape, 5.0);
        let pv = TensorF::full(&p_shape, 6.0);
        let ppos: Vec<i32> = (12..16).collect();
        let a = DecodeBuffer::new(&d, &meta, &pk, &pv, &ppos);
        let b = DecodeBuffer::new(&d, &reference, &pk, &pv, &ppos);
        assert_eq!(a.k.data(), b.k.data(), "materialized keys");
        assert_eq!(a.v.data(), b.v.data());
        assert_eq!(a.gpos.data(), b.gpos.data());
        assert_eq!(a.valid.data(), b.valid.data());
    }

    #[test]
    fn decode_buffer_materializes_keys_at_storage_positions() {
        let d = dims();
        let ctx = AssembledContext::new(&d, 8, &[chunk(1, 4, 1.0)]).unwrap();
        let p_shape = [d.n_layers, d.prompt_len, d.n_heads, d.head_dim];
        let buf = DecodeBuffer::new(
            &d,
            &ctx,
            &TensorF::zeros(&p_shape),
            &TensorF::zeros(&p_shape),
            &[4, 5, 6, 7],
        );
        // Row 3 stores raw 1.0s at chunk-local position 3: the buffer must
        // hold snap(rotate(raw, 3)), not the raw bytes.
        let row = d.n_heads * d.head_dim;
        let mut want = vec![1.0f32; row];
        rope::materialize_row(&mut want, d.n_heads, d.head_dim, 3, d.rope_theta);
        let got: Vec<f32> = (0..row)
            .map(|i| buf.k.at(&[0, 3, i / d.head_dim, i % d.head_dim]))
            .collect();
        assert_eq!(got, want);
        // ...and position 0 rows are snapped too (eager always quantized).
        let got0 = buf.k.at(&[0, 0, 0, 0]);
        assert_eq!(got0, rope::snap(1.0));
        // values are copied untouched
        assert_eq!(buf.v.at(&[0, 3, 0, 0]), 10.0);
    }

    #[test]
    fn patch_rejects_shape_mismatches() {
        let d = dims();
        let mut ctx = AssembledContext::new(&d, 16, &[chunk(1, 8, 1.0)]).unwrap();
        let good = TensorF::full(&[d.n_layers, 4, d.n_heads, d.head_dim], 1.0);
        // wrong layer count
        let bad_l = TensorF::full(&[d.n_layers + 1, 4, d.n_heads, d.head_dim], 1.0);
        assert!(ctx.patch(&[0], &[0], 1, &bad_l, &good).is_err());
        // wrong head dim
        let bad_dh = TensorF::full(&[d.n_layers, 4, d.n_heads, d.head_dim + 1], 1.0);
        assert!(ctx.patch(&[0], &[0], 1, &good, &bad_dh).is_err());
        // k/v disagree on S
        let bad_s = TensorF::full(&[d.n_layers, 5, d.n_heads, d.head_dim], 1.0);
        assert!(ctx.patch(&[0], &[0], 1, &good, &bad_s).is_err());
        // count exceeds slot list
        assert!(ctx.patch(&[0], &[0], 2, &good, &good).is_err());
        // count exceeds S capacity
        let slots = [0, 1, 2, 3, 4];
        assert!(ctx.patch(&slots, &slots, 5, &good, &good).is_err());
        // and a well-formed call still succeeds
        assert!(ctx.patch(&[0], &[0], 1, &good, &good).is_ok());
    }

    #[test]
    fn decode_buffer_layout_and_append() {
        let d = dims();
        let ctx = AssembledContext::new(&d, 16, &[chunk(1, 8, 1.0)]).unwrap();
        let p_shape = [d.n_layers, d.prompt_len, d.n_heads, d.head_dim];
        let pk = TensorF::full(&p_shape, 5.0);
        let pv = TensorF::full(&p_shape, 6.0);
        let ppos: Vec<i32> = (8..12).collect();
        let mut buf = DecodeBuffer::new(&d, &ctx, &pk, &pv, &ppos);
        assert_eq!(buf.capacity(), 16 + 4 + 3);
        assert_eq!(buf.k.at(&[0, 16, 0, 0]), 5.0, "prompt rows after ctx block");
        assert_eq!(buf.gpos.data()[16], 8);
        assert_eq!(buf.next_pos, 12);
        let row_shape = [d.n_layers, d.n_heads, d.head_dim];
        buf.append(&TensorF::full(&row_shape, 1.5), &TensorF::full(&row_shape, 2.5))
            .unwrap();
        assert_eq!(buf.k.at(&[1, 20, 0, 0]), 1.5);
        assert_eq!(buf.gpos.data()[20], 12);
        assert_eq!(buf.valid.data()[20], 1.0);
        // fill to capacity -> error
        for _ in 0..2 {
            buf.append(&TensorF::full(&row_shape, 0.0), &TensorF::full(&row_shape, 0.0))
                .unwrap();
        }
        assert!(buf
            .append(&TensorF::full(&row_shape, 0.0), &TensorF::full(&row_shape, 0.0))
            .is_err());
    }

    #[test]
    fn from_parts_rejects_shape_mismatches() {
        let d = dims();
        let x = 8usize;
        let k = TensorF::zeros(&[d.n_layers, x, d.n_heads, d.head_dim]);
        let v = k.clone();
        let gpos: Vec<i32> = (0..x as i32).collect();
        let valid = vec![1.0f32; x];
        assert!(DecodeBuffer::from_parts(&d, &k, &v, &gpos, &valid, x as i32).is_ok());
        // gpos too short
        assert!(DecodeBuffer::from_parts(&d, &k, &v, &gpos[..x - 1], &valid, 0).is_err());
        // valid too long
        let long = vec![1.0f32; x + 1];
        assert!(DecodeBuffer::from_parts(&d, &k, &v, &gpos, &long, 0).is_err());
        // wrong layer count
        let bad = TensorF::zeros(&[d.n_layers + 1, x, d.n_heads, d.head_dim]);
        assert!(DecodeBuffer::from_parts(&d, &bad, &v, &gpos, &valid, 0).is_err());
        // k/v shape disagreement
        let bad_v = TensorF::zeros(&[d.n_layers, x + 1, d.n_heads, d.head_dim]);
        assert!(DecodeBuffer::from_parts(&d, &k, &bad_v, &gpos, &valid, 0).is_err());
    }
}
