//! The `regex`/`json` decode policies — the guide subsystem's plan-registry
//! front-ends.
//!
//! These are the first policies registered through the runtime-extensible
//! `Registry` rather than compiled into an enum: the plan layer only sees
//! the `DecodePolicy` trait, and an out-of-tree policy family registered
//! via `Registry::with_policies` is indistinguishable from these.

use anyhow::Result;

use crate::plan::DecodePolicy;
use crate::vocab::Vocab;

use super::dfa::Guide;
use super::lang;

/// The `json` preset's expansion: one key token then the fact's two value
/// tokens — the fact-vocabulary analog of `{"key": [v1, v2]}`, matching
/// the value-fact payload shape the eval tasks emit.
pub const JSON_SHAPE: &str = "key.val.val";

#[derive(Clone, Debug, PartialEq)]
enum Kind {
    Regex(String),
    Json,
}

/// A `decode=` plan stage backed by a compiled [`Guide`].
#[derive(Clone, Debug, PartialEq)]
pub struct GuidePolicy {
    kind: Kind,
}

impl GuidePolicy {
    /// `decode=regex:<pattern>` — the pattern is syntax-checked here, at
    /// plan-parse time; literal index ranges are checked against the live
    /// vocab when the guide compiles at prep time.
    pub fn regex(pattern: &str) -> Result<GuidePolicy> {
        lang::parse(pattern)?;
        Ok(GuidePolicy {
            kind: Kind::Regex(pattern.to_string()),
        })
    }

    /// `decode=json` — the fixed [`JSON_SHAPE`] preset.  Renders as the
    /// preset name, not its expansion, so the canonical form round-trips.
    pub fn json() -> GuidePolicy {
        GuidePolicy { kind: Kind::Json }
    }

    /// The guide-language pattern this policy compiles.
    pub fn pattern(&self) -> &str {
        match &self.kind {
            Kind::Regex(p) => p,
            Kind::Json => JSON_SHAPE,
        }
    }
}

impl DecodePolicy for GuidePolicy {
    fn name(&self) -> &'static str {
        match self.kind {
            Kind::Regex(_) => "regex",
            Kind::Json => "json",
        }
    }

    fn render(&self) -> String {
        match &self.kind {
            Kind::Regex(p) => format!("regex:{p}"),
            Kind::Json => "json".into(),
        }
    }

    fn compile(&self, vocab: &Vocab) -> Result<Guide> {
        Guide::compile(self.pattern(), vocab)
    }

    fn clone_box(&self) -> Box<dyn DecodePolicy> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regex_policy_syntax_checks_at_construction() {
        assert!(GuidePolicy::regex("val.val").is_ok());
        assert!(GuidePolicy::regex("val;val").is_err());
        assert!(GuidePolicy::regex("").is_err());
    }

    #[test]
    fn renders_are_canonical_atoms() {
        let r = GuidePolicy::regex("key.(val|filler)*").unwrap();
        assert_eq!(r.render(), "regex:key.(val|filler)*");
        assert_eq!(r.name(), "regex");
        let j = GuidePolicy::json();
        assert_eq!(j.render(), "json");
        assert_eq!(j.name(), "json");
        assert_eq!(j.pattern(), JSON_SHAPE);
    }

    #[test]
    fn json_preset_compiles_to_the_shape_guide() {
        let v = Vocab::default();
        let viaj = GuidePolicy::json().compile(&v).unwrap();
        let direct = Guide::compile(JSON_SHAPE, &v).unwrap();
        assert_eq!(viaj, direct);
        assert!(viaj.accepts(&[v.key_base, v.val_base, v.val_base + 1]));
        assert!(!viaj.accepts(&[v.key_base, v.val_base]));
    }

    #[test]
    fn out_of_range_literal_fails_at_compile_not_parse() {
        let p = GuidePolicy::regex("k99").unwrap();
        assert!(p.compile(&Vocab::default()).is_err());
    }
}
