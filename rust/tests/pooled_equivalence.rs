//! Property test: the pooled / assemble-once / in-place / resident-literal
//! query path is BIT-IDENTICAL to the fresh-allocation reference path at
//! every stage — across sequences of queries that actually reuse buffers,
//! with §4.3 reorder and recompute-patching combined — and does it within
//! the copy budget (one full-context copy + one decode-literal build per
//! steady-state query).
//!
//! This exercises the full host-side buffer machinery without model
//! artifacts; `tests/integration.rs` adds the artifact-gated end-to-end
//! `QueryResult` comparison over the real executables.

use std::sync::Arc;

use infoflow_kv::kvcache::{
    counters, AssembledContext, BufferPool, ChunkKv, DecodeBuffer,
};
use infoflow_kv::manifest::ModelDims;
use infoflow_kv::runtime::resident::ResidentDecodeKv;
use infoflow_kv::tensor::TensorF;
use infoflow_kv::util::{prop, rng::Rng};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 144,
        d_model: 64,
        n_layers: 3,
        n_heads: 2,
        head_dim: 4,
        d_ff: 128,
        rope_theta: 10000.0,
        chunk: 8,
        prompt_len: 4,
        sel_budget: 4,
        answer_buf: 3,
        dev_layers: 2,
    }
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> TensorF {
    let n: usize = shape.iter().product();
    TensorF::from_vec(shape, (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect())
        .unwrap()
}

fn rand_chunk(rng: &mut Rng, id: u64, len: usize) -> Arc<ChunkKv> {
    let d = dims();
    let shape = [d.n_layers, len, d.n_heads, d.head_dim];
    Arc::new(ChunkKv {
        id,
        tokens: (0..len as i32).map(|t| t + id as i32 * 100).collect(),
        k: rand_tensor(rng, &shape),
        v: rand_tensor(rng, &shape),
    })
}

fn rand_permutation(rng: &mut Rng, n: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    order.sort_by_key(|&i| keys[i]);
    order
}

struct QueryPlan {
    chunks: Vec<Arc<ChunkKv>>,
    order: Vec<usize>,
    // patch inputs (shared verbatim by both paths)
    slots: Vec<i32>,
    sel_gpos: Vec<i32>,
    count: usize,
    new_k: TensorF,
    new_v: TensorF,
    // decode inputs
    prompt_k: TensorF,
    prompt_v: TensorF,
    prompt_pos: Vec<i32>,
    appends: Vec<(TensorF, TensorF)>,
}

fn random_plan(rng: &mut Rng, bucket: usize) -> QueryPlan {
    let d = dims();
    let nc = 1 + rng.below(bucket / d.chunk);
    let chunks: Vec<_> =
        (0..nc).map(|i| rand_chunk(rng, i as u64, d.chunk)).collect();
    let n = nc * d.chunk;
    let order = rand_permutation(rng, nc);
    let s_cap = d.sel_budget;
    let count = rng.below(s_cap + 1);
    let slots: Vec<i32> = (0..s_cap).map(|_| rng.below(n) as i32).collect();
    let sel_gpos: Vec<i32> = slots.iter().map(|&s| s + 1).collect();
    let sel_shape = [d.n_layers, s_cap, d.n_heads, d.head_dim];
    let pshape = [d.n_layers, d.prompt_len, d.n_heads, d.head_dim];
    let row_shape = [d.n_layers, d.n_heads, d.head_dim];
    let n_appends = rng.below(d.answer_buf + 1);
    QueryPlan {
        chunks,
        order,
        slots,
        sel_gpos,
        count,
        new_k: rand_tensor(rng, &sel_shape),
        new_v: rand_tensor(rng, &sel_shape),
        prompt_k: rand_tensor(rng, &pshape),
        prompt_v: rand_tensor(rng, &pshape),
        prompt_pos: (n as i32..(n + d.prompt_len) as i32).collect(),
        appends: (0..n_appends)
            .map(|_| (rand_tensor(rng, &row_shape), rand_tensor(rng, &row_shape)))
            .collect(),
    }
}

/// The pre-refactor shape: fresh context per stage, host decode buffer.
fn reference_path(d: &ModelDims, bucket: usize, plan: &QueryPlan) -> (AssembledContext, DecodeBuffer) {
    let permuted: Vec<_> = plan.order.iter().map(|&i| plan.chunks[i].clone()).collect();
    let mut ctx = AssembledContext::new(d, bucket, &permuted).unwrap();
    ctx.patch(&plan.slots, &plan.sel_gpos, plan.count, &plan.new_k, &plan.new_v)
        .unwrap();
    let mut buf =
        DecodeBuffer::new(d, &ctx, &plan.prompt_k, &plan.prompt_v, &plan.prompt_pos);
    for (nk, nv) in &plan.appends {
        buf.append(nk, nv).unwrap();
    }
    (ctx, buf)
}

#[test]
fn pooled_path_is_bit_identical_to_reference_across_reuse() {
    let d = dims();
    let bucket = 64usize;
    let pool = BufferPool::new();
    let mut warmed = false;
    prop::check(40, |rng: &mut Rng| {
        let plan = random_plan(rng, bucket);

        // pooled / in-place / resident path, counters measured around it
        let before = counters::snapshot();
        let mut ctx = pool.checkout(&d, bucket, &plan.chunks).unwrap();
        ctx.permute_chunks_in_place(&plan.order).unwrap();
        ctx.patch(&plan.slots, &plan.sel_gpos, plan.count, &plan.new_k, &plan.new_v)
            .unwrap();
        let mut kv =
            ResidentDecodeKv::from_context(&d, &ctx, &plan.prompt_k, &plan.prompt_v, &plan.prompt_pos)
                .unwrap();
        for (nk, nv) in &plan.appends {
            kv.append(nk, nv).unwrap();
        }
        // counter delta captured BEFORE the reference path runs, so it
        // covers only the pooled path's work
        let delta = counters::snapshot().since(&before);

        // stage 1: the mutated context equals a freshly assembled one
        let (ref_ctx, ref_buf) = reference_path(&d, bucket, &plan);
        prop::assert_prop(ctx.chunk_lens == ref_ctx.chunk_lens, "chunk_lens differ")?;
        prop::assert_prop(ctx.tokens.data() == ref_ctx.tokens.data(), "tokens differ")?;
        prop::assert_prop(ctx.gpos.data() == ref_ctx.gpos.data(), "gpos differ")?;
        prop::assert_prop(ctx.valid.data() == ref_ctx.valid.data(), "valid differ")?;
        prop::assert_prop(ctx.k.data() == ref_ctx.k.data(), "ctx k differs")?;
        prop::assert_prop(ctx.v.data() == ref_ctx.v.data(), "ctx v differs")?;
        drop(ctx); // back to the pool, as in the pipeline

        // stage 2: the resident literal equals the reference decode buffer
        prop::assert_prop(
            kv.k_host().unwrap().data() == ref_buf.k.data(),
            "decode k differs",
        )?;
        prop::assert_prop(
            kv.v_host().unwrap().data() == ref_buf.v.data(),
            "decode v differs",
        )?;
        prop::assert_prop(
            kv.gpos_host().unwrap().data() == ref_buf.gpos.data(),
            "decode gpos differs",
        )?;
        prop::assert_prop(
            kv.valid_host().unwrap().data() == ref_buf.valid.data(),
            "decode valid differs",
        )?;
        prop::assert_prop(
            kv.next_row == ref_buf.next_row && kv.next_pos == ref_buf.next_pos,
            "decode cursors differ",
        )?;

        // stage 3: the copy budget, once the pool is warm
        if warmed {
            prop::assert_prop(
                delta.full_kv_copies == 1,
                format!("steady state did {} full copies, want 1", delta.full_kv_copies),
            )?;
            prop::assert_prop(delta.ctx_allocs == 0, "steady state allocated a context")?;
        }
        warmed = true;
        prop::assert_prop(
            delta.decode_uploads_full == 1,
            format!("{} decode-literal builds, want 1", delta.decode_uploads_full),
        )?;
        prop::assert_prop(
            delta.decode_row_updates == plan.appends.len() as u64,
            "append count mismatch",
        )?;
        Ok(())
    });
}
