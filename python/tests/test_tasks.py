"""Fact micro-language generator invariants (the python half of the
python/rust grammar contract; rust/src/workload mirrors these)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks


class TestVocabLayout:
    def test_ranges_are_disjoint_and_cover(self):
        spec = tasks.vocab_spec()
        assert spec["key_base"] >= 16
        assert spec["val_base"] == spec["key_base"] + spec["num_keys"]
        assert spec["filler_base"] == spec["val_base"] + spec["num_vals"]
        assert spec["filler_base"] + spec["num_filler"] == spec["vocab"]

    def test_specials_below_key_base(self):
        for tok in (tasks.PAD, tasks.BOS, tasks.QUERY, tasks.ANSWER, tasks.SEP,
                    tasks.KEYMARK, tasks.VALMARK, tasks.EOS, tasks.IMG,
                    tasks.ROW, tasks.COL, tasks.HOP):
            assert 0 <= tok < tasks.KEY_BASE


@settings(max_examples=40, deadline=None)
@given(
    task=st.sampled_from(tasks.TASKS),
    n_chunks=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_sample_wellformed(task, n_chunks, seed):
    rng = np.random.default_rng(seed)
    chunk, prompt_len = 64, 16
    s = tasks.make_sample(rng, task, n_chunks * chunk, chunk, prompt_len)
    assert len(s.ctx) == n_chunks * chunk
    assert len(s.prompt) == prompt_len
    assert len(s.answer) == tasks.ANSWER_LEN
    assert all(0 <= t < tasks.VOCAB for t in s.ctx + s.prompt + s.answer)
    # prompt is front-padded and ends with ANSWER
    assert s.prompt[-1] == tasks.ANSWER
    body = [t for t in s.prompt if t != tasks.PAD]
    assert body[0] == tasks.QUERY
    # answer payload tokens are values; tail is EOS
    assert s.answer[-1] == tasks.EOS or s.answer.count(tasks.EOS) >= 1
    for t in s.answer:
        assert t == tasks.EOS or tasks.VAL_BASE <= t < tasks.VAL_BASE + tasks.NUM_VALS
    # needle chunks are in range
    for c in s.needle_chunks:
        assert 0 <= c < n_chunks


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n_chunks=st.integers(2, 6))
def test_facts_never_straddle_chunks(seed, n_chunks):
    """A KEYMARK fact must be entirely inside one chunk (passage-split
    soundness depends on this)."""
    rng = np.random.default_rng(seed)
    chunk = 64
    s = tasks.make_sample(rng, "onehop", n_chunks * chunk, chunk, 16)
    for i, t in enumerate(s.ctx):
        if t == tasks.KEYMARK:
            assert i // chunk == (i + 4) // chunk, "fact crosses chunk boundary"
            assert tasks.VAL_BASE <= s.ctx[i + 2] < tasks.VAL_BASE + tasks.NUM_VALS
            assert s.ctx[i + 4] == tasks.SEP


def test_recency_answer_is_last_occurrence():
    rng = np.random.default_rng(7)
    for _ in range(20):
        s = tasks.make_sample(rng, "recency", 256, 64, 16)
        qk = [t for t in s.prompt if t != tasks.PAD][1]
        occurrences = [
            i for i in range(len(s.ctx) - 4)
            if s.ctx[i] == tasks.KEYMARK and s.ctx[i + 1] == qk
        ]
        assert len(occurrences) >= 2, "recency sample must have duplicates"
        last = occurrences[-1]
        assert s.answer[0] == s.ctx[last + 2]
        assert s.answer[1] == s.ctx[last + 3]


def test_twohop_requires_both_facts():
    rng = np.random.default_rng(8)
    for _ in range(20):
        s = tasks.make_sample(rng, "twohop", 256, 64, 16)
        body = [t for t in s.prompt if t != tasks.PAD]
        assert body[:2] == [tasks.QUERY, tasks.HOP]
        k1 = body[2]
        # find the link fact and the value fact in ctx
        link = value = None
        for i in range(len(s.ctx) - 4):
            if (s.ctx[i] == tasks.KEYMARK and s.ctx[i + 1] == k1
                    and s.ctx[i + 2] == tasks.HOP):
                link = s.ctx[i + 3]
        assert link is not None
        for i in range(len(s.ctx) - 4):
            if (s.ctx[i] == tasks.KEYMARK and s.ctx[i + 1] == link
                    and s.ctx[i + 2] != tasks.HOP):
                value = (s.ctx[i + 2], s.ctx[i + 3])
        assert value == (s.answer[0], s.answer[1])


def test_sample_batch_shapes_and_mask():
    rng = np.random.default_rng(9)
    toks, mask = tasks.sample_batch(rng, tasks.LLM_MIX, 4, 128)
    assert toks.shape == (4, 128 + 16 + tasks.ANSWER_LEN)
    assert mask.shape == toks.shape
    # loss mask covers exactly the answer region
    assert float(mask[:, : 128 + 16].sum()) == 0.0
    assert float(mask[:, 128 + 16 :].sum()) == 4 * tasks.ANSWER_LEN


def test_mixes_are_distributions():
    for mix in (tasks.LLM_MIX, tasks.VLM_MIX):
        assert abs(sum(mix.values()) - 1.0) < 1e-9
        assert set(mix) == set(tasks.TASKS)
