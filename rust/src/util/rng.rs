//! Deterministic, seedable RNG (xoshiro256** seeded via SplitMix64).
//!
//! Every workload generator, the reordering tie-breaks and the property-test
//! runner use this so that all experiment tables are exactly reproducible
//! from a seed recorded in EXPERIMENTS.md.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-sample / per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (for Poisson request traces).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n) (Floyd's algorithm would be nicer;
    /// partial Fisher-Yates is fine at our sizes).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let mut v = r.choose_distinct(20, 10);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), 10);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
