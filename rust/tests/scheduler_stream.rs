//! Continuous-batching serving conformance, artifact-free (stub runtime).
//!
//! The decode-scheduler rearchitecture must be INVISIBLE in results: a
//! query served through the interleaving scheduler — its tokens streamed at
//! emission — is token-for-token identical to `Pipeline::answer_plan` run
//! locally.  This suite locks that in across the full 4-geometry × method
//! conformance grid (all 20 queries in flight at once through ONE worker,
//! so the interleaving genuinely happens), plus the lifecycle properties
//! the new machinery promises: fairness under churn, shutdown draining
//! every parked task and closing every stream channel, and the prefetch
//! priority queue warming the next-to-dispatch request first.
//!
//! Each test prints a `sched-test: <name> ok` marker; CI tallies them into
//! the job summary so a silently-skipped scheduler suite is visible.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use infoflow_kv::config::MethodSpec;
use infoflow_kv::coordinator::batcher::BatcherConfig;
use infoflow_kv::coordinator::{DecodeScheduler, PrefetchFn, Server, ServerConfig};
use infoflow_kv::geometry::RopeGeometry;
use infoflow_kv::kvcache::ChunkStore;
use infoflow_kv::pipeline::Pipeline;
use infoflow_kv::runtime::exec::ModelSession;
use infoflow_kv::runtime::Runtime;
use infoflow_kv::util::rng::Rng;
use infoflow_kv::workload::EpisodeGen;

const STUB_SEED: u64 = 2603;
const BUDGET: usize = 8;

fn stub_pipeline(rt: &Arc<Runtime>) -> Pipeline {
    Pipeline::new(ModelSession::new(rt.clone(), "stub").unwrap()).unwrap()
}

/// The conformance grid: every method × geometry cell (geometry only moves
/// through `ours`, but serving each cell exercises the scheduler at width).
fn grid_methods(geometry: RopeGeometry) -> Vec<(&'static str, MethodSpec)> {
    vec![
        ("baseline", MethodSpec::Baseline),
        ("norecompute", MethodSpec::NoRecompute),
        (
            "ours",
            MethodSpec::Ours { budget: BUDGET, geometry, norm_layer: 2, reorder: false },
        ),
        ("cacheblend", MethodSpec::CacheBlend { budget: BUDGET }),
        ("epic", MethodSpec::Epic { budget: BUDGET }),
    ]
}

#[test]
fn streaming_grid_is_bit_identical_to_answer_plan() {
    let rt = Arc::new(Runtime::stub(STUB_SEED));
    let reference = stub_pipeline(&rt);
    let genr = EpisodeGen::new(reference.vocab.clone(), rt.manifest.model.chunk);
    // ONE worker, wide interleave: all 20 grid queries decode concurrently
    // through the same scheduler — the hardest case for bit-equality.
    let server = Server::spawn_pool(
        vec![stub_pipeline(&rt)],
        ChunkStore::new(1 << 30),
        ServerConfig { max_interleave: 32, ..ServerConfig::default() },
    );

    struct Case {
        label: String,
        expect: Vec<i32>,
        tokens: std::sync::mpsc::Receiver<i32>,
        resp: std::sync::mpsc::Receiver<infoflow_kv::coordinator::Response>,
    }
    let mut cases: Vec<Case> = Vec::new();
    for (gi, geometry) in RopeGeometry::ALL.into_iter().enumerate() {
        for (mname, method) in grid_methods(geometry) {
            let mut rng = Rng::new(300 + gi as u64);
            let e = genr.onehop(&mut rng, 3);
            let plan = method.to_plan();
            // Local reference on a fresh store: the ground truth answer.
            let store = ChunkStore::new(1 << 30);
            let (chunks, _) = reference.prepare_chunks(&store, &e.chunks).unwrap();
            let expect = reference.answer_plan(&chunks, &e.prompt, &plan).unwrap();
            let (tokens, resp) = server.query_plan_stream(e, plan).unwrap();
            cases.push(Case {
                label: format!("geom={} method={mname}", geometry.name()),
                expect: expect.answer,
                tokens,
                resp,
            });
        }
    }
    let mut any_multi_token = false;
    for c in cases {
        let resp = c.resp.recv().unwrap_or_else(|_| panic!("{}: dropped", c.label));
        assert_eq!(resp.answer, c.expect, "{}: served != local answer_plan", c.label);
        let streamed: Vec<i32> = c.tokens.iter().collect();
        assert_eq!(streamed, c.expect, "{}: streamed tokens != final answer", c.label);
        assert!(
            resp.ttft_s <= resp.total_s + 1e-9,
            "{}: measured ttft {} exceeds total {}",
            c.label,
            resp.ttft_s,
            resp.total_s
        );
        any_multi_token |= c.expect.len() >= 2;
        println!("sched-test: streaming_grid {} tokens={} ok", c.label, streamed.len());
    }
    // Measured wall-clock reservoirs, distinct from the stage sums.
    let dump = server.metrics_json().to_string_pretty();
    assert!(dump.contains("\"ttft\""), "metrics_json must carry measured ttft");
    assert!(
        dump.contains("ttft_stage_sum"),
        "metrics_json must keep the stage-sum ttft for attribution"
    );
    assert!(dump.contains("decode_ticks"), "scheduler must tick through metrics");
    if any_multi_token {
        assert!(dump.contains("\"tbt\""), "multi-token answers must record tbt");
    }
    server.shutdown();
}

#[test]
fn fairness_no_task_starves_beyond_max_interleave_ticks_under_churn() {
    // Synthetic tasks fed from 3 producer threads; the driver admits
    // between ticks, exactly like a scheduled worker.  Every task must be
    // visited at least once every `max_interleave` ticks of its lifetime.
    const MAX_INTERLEAVE: usize = 4;
    const PER_PRODUCER: usize = 20;
    struct Fake {
        need: usize,
        steps: usize,
        admitted_tick: u64,
        visits: Vec<u64>,
    }
    let (tx, rx) = std::sync::mpsc::channel::<usize>();
    let mut producers = Vec::new();
    for p in 0..3u64 {
        let tx = tx.clone();
        producers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(40 + p);
            for _ in 0..PER_PRODUCER {
                tx.send(1 + rng.below(5)).unwrap();
                std::thread::sleep(Duration::from_micros(200));
            }
        }));
    }
    drop(tx);

    let mut sched: DecodeScheduler<Fake> = DecodeScheduler::new(MAX_INTERLEAVE);
    let mut pending: Vec<usize> = Vec::new();
    let mut done: Vec<Fake> = Vec::new();
    let mut disconnected = false;
    while !disconnected || !pending.is_empty() || !sched.is_empty() {
        loop {
            match rx.try_recv() {
                Ok(need) => pending.push(need),
                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        while sched.has_capacity() && !pending.is_empty() {
            let need = pending.remove(0);
            sched
                .admit(Fake {
                    need,
                    steps: 0,
                    admitted_tick: sched.ticks(),
                    visits: Vec::new(),
                })
                .unwrap_or_else(|_| panic!("capacity was checked"));
        }
        if sched.is_empty() {
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        let tick_no = sched.ticks() + 1;
        done.extend(sched.tick(|f| {
            f.visits.push(tick_no);
            f.steps += 1;
            f.steps >= f.need
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    assert_eq!(done.len(), 3 * PER_PRODUCER, "every task must complete");
    let bound = MAX_INTERLEAVE as u64;
    for (i, f) in done.iter().enumerate() {
        assert_eq!(f.visits.len(), f.need, "task {i} visit count");
        let first = *f.visits.first().unwrap();
        assert!(
            first - f.admitted_tick <= bound,
            "task {i} waited {} ticks for its first step (bound {bound})",
            first - f.admitted_tick
        );
        for w in f.visits.windows(2) {
            assert!(
                w[1] - w[0] <= bound,
                "task {i} starved {} ticks between steps (bound {bound})",
                w[1] - w[0]
            );
        }
    }
    assert!(
        sched.max_starve_ticks() <= bound,
        "scheduler-observed starvation {} exceeds the {bound}-tick bound",
        sched.max_starve_ticks()
    );
    println!(
        "sched-test: fairness tasks={} ticks={} max_starve={} ok",
        done.len(),
        sched.ticks(),
        sched.max_starve_ticks()
    );
}

#[test]
fn shutdown_drains_parked_tasks_and_closes_stream_channels() {
    let rt = Arc::new(Runtime::stub(STUB_SEED));
    let genr = EpisodeGen::new(stub_pipeline(&rt).vocab.clone(), rt.manifest.model.chunk);
    // Narrow interleave so some of the 6 queries are still in the worker's
    // pending queue (not even prepped) when shutdown starts.
    let server = Server::spawn_pool(
        vec![stub_pipeline(&rt)],
        ChunkStore::new(1 << 30),
        ServerConfig { max_interleave: 2, ..ServerConfig::default() },
    );
    let plan = MethodSpec::ours(BUDGET).to_plan();
    let mut pend = Vec::new();
    for i in 0..6u64 {
        let mut rng = Rng::new(500 + i);
        let e = genr.onehop(&mut rng, 2);
        pend.push(server.query_plan_stream(e, plan.clone()).unwrap());
    }
    // Shut down immediately: the router drains its queue to the worker, the
    // worker finishes every parked + pending decode before exiting.
    server.shutdown();
    for (i, (tokens, resp)) in pend.into_iter().enumerate() {
        let resp = resp
            .try_recv()
            .unwrap_or_else(|_| panic!("request {i} was dropped during shutdown"));
        let streamed: Vec<i32> = tokens.try_iter().collect();
        assert_eq!(streamed, resp.answer, "request {i}: stream/answer mismatch");
        assert!(
            matches!(tokens.try_recv(), Err(std::sync::mpsc::TryRecvError::Disconnected)),
            "request {i}: stream channel left open (hung receiver)"
        );
    }
    println!("sched-test: shutdown_drain ok");
}

#[test]
fn front_of_queue_request_wins_the_prefetch_race() {
    // Regression for FIFO prefetch: the warm order must follow distance to
    // dispatch, not arrival.  Timeline: R0's warm wedges the (single)
    // prefetcher; R1+R2 arrive, queue their jobs at distances 1 and 2, get
    // dispatched and served; then R3 arrives into an EMPTY batcher —
    // distance 0, the next request a worker will see.  When the prefetcher
    // is released, R3's chunks must warm before the stale R1/R2 jobs even
    // though those were scheduled first.
    let order: Arc<Mutex<Vec<i32>>> = Arc::new(Mutex::new(Vec::new()));
    let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    let warm_fn: PrefetchFn = {
        let order = order.clone();
        let mut first = true;
        Box::new(move |chunks: &[Vec<i32>]| {
            if first {
                first = false;
                let _ = started_tx.send(());
                let _ = release_rx.recv(); // wedge until the test releases
            }
            order.lock().unwrap().push(chunks[0][0]);
        })
    };
    let handler: infoflow_kv::coordinator::Handler = Box::new(|_req| {
        Ok(infoflow_kv::coordinator::Served {
            answer: vec![1],
            ttft_s: 1e-6,
            total_s: 1e-6,
            stages: vec![],
        })
    });
    let server = Server::spawn_handlers_with_prefetch(
        vec![handler],
        vec![warm_fn],
        ServerConfig {
            // A wide batch + a generous window: R0..R2 reliably coalesce
            // into ONE dispatch (even on a loaded CI box), clearing the
            // batcher before R3 arrives.
            batch: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(200) },
            queue_cap: 16,
            ..ServerConfig::default()
        },
    );
    let submit = |tag: i32| {
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        server
            .submit(infoflow_kv::coordinator::Request {
                episode: infoflow_kv::workload::Episode {
                    chunks: vec![vec![tag, tag + 1, tag + 2]],
                    prompt: vec![4],
                    answer: vec![5],
                    needle_chunks: vec![],
                    task: "test",
                },
                plan: MethodSpec::Baseline.to_plan(),
                respond: rtx,
                stream: None,
                session_id: None,
            })
            .unwrap();
        rrx
    };
    let deadline = Instant::now() + Duration::from_secs(5);
    let r0 = submit(100);
    started_rx
        .recv_timeout(Duration::from_secs(5))
        .expect("prefetcher never started R0's warm");
    let r1 = submit(200);
    let r2 = submit(300);
    // Wait until R0..R2 are fully served — their batch has dispatched, the
    // batcher is empty again.
    for r in [r0, r1, r2] {
        r.recv_timeout(Duration::from_secs(5)).expect("early request not served");
    }
    let r3 = submit(400);
    // R3's job lands at distance 0; poll until the router scheduled it.
    while server.metrics().counter("prefetch_scheduled") < 4 {
        assert!(Instant::now() < deadline, "R3's prefetch job never scheduled");
        std::thread::sleep(Duration::from_millis(1));
    }
    release_tx.send(()).unwrap();
    r3.recv_timeout(Duration::from_secs(5)).expect("R3 not served");
    server.shutdown(); // drains the remaining warms
    let got = order.lock().unwrap().clone();
    assert_eq!(got.len(), 4, "every scheduled job must be warmed: {got:?}");
    assert_eq!(got[0], 100, "R0's warm was in flight first");
    assert_eq!(
        got[1], 400,
        "the next-to-dispatch request must out-warm earlier queued jobs: {got:?}"
    );
    println!("sched-test: prefetch_priority order={got:?} ok");
}
