//! Multi-query RAG serving: the paper's motivating workload.  A document
//! pool is prefilled once; a Poisson stream of queries retrieves subsets
//! and the threaded coordinator serves them with dynamic batching, chunk-
//! cache reuse and selective recomputation.  Reports throughput, latency
//! percentiles, cache hit rate and answer quality.
//!
//! ```bash
//! cargo run --release --example rag_serving -- [requests] [rate] [workers]
//! ```

use std::path::Path;
use std::sync::Arc;

use infoflow_kv::config::MethodSpec;
use infoflow_kv::coordinator::{Server, ServerConfig};
use infoflow_kv::eval::token_f1;
use infoflow_kv::kvcache::ChunkStore;
use infoflow_kv::pipeline::Pipeline;
use infoflow_kv::runtime::exec::ModelSession;
use infoflow_kv::runtime::Runtime;
use infoflow_kv::workload::traces::{self, TraceConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let rate: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6.0);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2).max(1);

    let runtime = Arc::new(Runtime::load(Path::new("artifacts"))?);
    let backbone = runtime.backbone_names().first().cloned()
        .expect("no backbones — run `make artifacts`");
    // One session per worker; weights/executables are shared via the Runtime.
    let mut pipelines = Vec::with_capacity(workers);
    for _ in 0..workers {
        pipelines.push(Pipeline::new(ModelSession::new(runtime.clone(), &backbone)?)?);
    }
    let vocab = pipelines[0].vocab.clone();
    let chunk = runtime.manifest.model.chunk;

    let cfg = TraceConfig {
        rate,
        n_requests,
        doc_pool: 10,
        chunks_per_request: 4,
        seed: 21,
    };
    let trace = traces::generate(&vocab, chunk, &cfg);
    println!(
        "rag_serving: {} requests @ poisson {}/s over {} shared docs ({backbone}, {workers} workers)",
        cfg.n_requests, cfg.rate, cfg.doc_pool
    );

    let server = Server::spawn_pool(
        pipelines,
        ChunkStore::new(256 << 20),
        ServerConfig { queue_cap: 128, ..ServerConfig::default() },
    );

    let t0 = std::time::Instant::now();
    let mut f1_sum = 0.0;
    let mut ok = 0usize;
    for req in trace {
        let wait = req.at_s - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wait));
        }
        let gold = req.episode.answer.clone();
        let resp = server.query(req.episode, MethodSpec::ours(16))?;
        f1_sum += token_f1(&resp.answer, &gold);
        ok += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\nserved {ok} requests in {wall:.1}s = {:.2} req/s", ok as f64 / wall);
    println!("mean F1: {:.3}", f1_sum / ok.max(1) as f64);
    let m = server.metrics();
    if let Some((mean, p50, p95)) = m.latency_summary("ttft") {
        println!(
            "ttft: mean {:.1} ms | p50 {:.1} ms | p95 {:.1} ms",
            mean * 1e3, p50 * 1e3, p95 * 1e3
        );
    }
    if let Some((mean, _, p95)) = m.latency_summary("queue") {
        println!("queueing: mean {:.1} ms | p95 {:.1} ms", mean * 1e3, p95 * 1e3);
    }
    if let Some(store) = server.store() {
        let st = store.stats();
        let total = (st.hits + st.misses).max(1);
        println!(
            "chunk cache: {} hits / {} misses ({:.0}% hit rate), {} evictions, lock wait {:.2} ms",
            st.hits,
            st.misses,
            st.hits as f64 / total as f64 * 100.0,
            st.evictions,
            store.lock_wait_s() * 1e3,
        );
    }
    println!("\nfull metrics:\n{}", server.metrics_json().to_string_pretty());
    server.shutdown();
    Ok(())
}
