//! Table 2: RoPE similarity (MoM / Max) between prompt positions and the
//! positions of the tokens each method selects — semantics blocked, purely
//! positional geometry (rust/src/rope.rs), two backbones x two datasets.

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::tables::{fmt4, Table};
use crate::rope;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::datasets::{eval_set, ChunkingMode, Dataset};

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let budget = args.usize_or("budget", 16)?;
    let d = ctx.runtime.manifest.model.clone();

    let backbones: Vec<String> = ["qwen-syn", "llama-syn"]
        .iter()
        .filter(|b| ctx.runtime.backbone_names().iter().any(|h| h == *b))
        .map(|s| s.to_string())
        .collect();
    let methods: Vec<(&str, MethodSpec)> = vec![
        ("Norm-based", MethodSpec::ours(budget)),
        ("CacheBlend", MethodSpec::CacheBlend { budget }),
        ("EPIC", MethodSpec::Epic { budget }),
    ];

    let mut table = Table::new(
        "Table 2: RoPE similarity of selected tokens (MoM / Max)",
        &["Model", "Method", "2Wiki MoM", "2Wiki Max", "Hotpot MoM", "Hotpot Max"],
    );
    let mut json_rows = vec![];
    for backbone in &backbones {
        let pipeline = ctx.pipeline(backbone)?;
        for (mname, method) in &methods {
            let mut cells = vec![backbone.clone(), mname.to_string()];
            let mut jrow = vec![
                ("model", Json::from(backbone.as_str())),
                ("method", Json::from(*mname)),
            ];
            for ds in [Dataset::TwoWikiMqa, Dataset::HotpotQa] {
                let episodes = eval_set(&pipeline.vocab, d.chunk, ds,
                                        ChunkingMode::PassageSplit, ctx.samples, ctx.seed);
                let store = ctx.store();
                let (mut mom, mut mx, mut n) = (0.0, 0.0, 0usize);
                for e in &episodes {
                    let (chunks, _) = pipeline.prepare_chunks(&store, &e.chunks)?;
                    let r = pipeline.answer(&chunks, &e.prompt, *method)?;
                    if r.selected_positions.is_empty() {
                        continue;
                    }
                    let nctx: usize = e.chunks.iter().map(|c| c.len()).sum();
                    let prompt_pos: Vec<i64> =
                        (nctx as i64..(nctx + d.prompt_len) as i64).collect();
                    let s = rope::similarity_stats(
                        &prompt_pos,
                        &r.selected_positions,
                        d.head_dim,
                        d.rope_theta,
                    );
                    mom += s.mean_of_max;
                    mx += s.max;
                    n += 1;
                }
                let n = n.max(1) as f64;
                cells.push(fmt4(mom / n));
                cells.push(fmt4(mx / n));
                jrow.push((ds.name(), Json::obj(vec![
                    ("mom", Json::from(mom / n)),
                    ("max", Json::from(mx / n)),
                ])));
            }
            println!("{}", crate::util::fmt_row(&cells, &[10, 11, 10, 10, 10, 10]));
            table.row(cells);
            json_rows.push(Json::obj(jrow));
        }
    }
    println!("\n{}", table.render());
    ctx.dump("table2", Json::Arr(json_rows), Some(table.to_csv()))?;
    Ok(())
}
