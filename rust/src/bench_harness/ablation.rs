//! Selection-quality ablation (beyond the paper's tables; DESIGN.md calls
//! for ablating the design choices): with the recomputation machinery held
//! fixed, sweep WHAT gets selected —
//!
//!   none    no recomputation (lower anchor)
//!   random  budget random context rows
//!   epic    chunk-initial rows
//!   norm    Eq.-7 attention-norm top-k (ours)
//!   oracle  the needle fact's rows (ground-truth selection, upper anchor
//!           for any selection strategy at this budget)
//!
//! This isolates the paper's core claim — that *which* tokens you recompute
//! is what matters — from the recomputation mechanics and the model's
//! ceiling (Baseline).

use anyhow::Result;

use super::context::BenchContext;
use crate::config::MethodSpec;
use crate::eval::metrics::token_f1;
use crate::eval::tables::{fmt4, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::vocab;
use crate::workload::needle::needle_episode;

pub fn run(args: &Args) -> Result<()> {
    let ctx = BenchContext::from_args(args)?;
    let backbone = ctx.backbone_or_default(args);
    let pipeline = ctx.pipeline(&backbone)?;
    let budget = args.usize_or("budget", 16)?;
    let chunk = ctx.runtime.manifest.model.chunk;
    let n_chunks = args.usize_or("chunks", 6)?;
    let samples = ctx.samples;

    let mut table = Table::new(
        &format!(
            "Ablation: selection quality at fixed budget {budget} \
             (needle task, {} tokens, {backbone})",
            n_chunks * chunk
        ),
        &["Selection", "F1", "needle-hit"],
    );
    let mut json_rows = vec![];

    let variants = ["none", "random", "epic", "norm", "oracle", "baseline"];
    for variant in variants {
        let store = ctx.store();
        let mut rng = Rng::new(ctx.seed ^ 0xAB1A);
        let mut f1 = 0.0;
        let mut hits = 0usize;
        for _ in 0..samples {
            let e = needle_episode(&pipeline.vocab, chunk, &mut rng, n_chunks, 0.7);
            let (chunks, _) = pipeline.prepare_chunks(&store, &e.chunks)?;
            let n: usize = e.chunks.iter().map(|c| c.len()).sum();
            let r = match variant {
                "none" => pipeline.answer(&chunks, &e.prompt, MethodSpec::NoRecompute)?,
                "baseline" => pipeline.answer(&chunks, &e.prompt, MethodSpec::Baseline)?,
                "norm" => pipeline.answer(&chunks, &e.prompt, MethodSpec::ours(budget))?,
                "epic" => pipeline.answer(
                    &chunks,
                    &e.prompt,
                    MethodSpec::Epic { budget },
                )?,
                "random" => {
                    let rows = rng.choose_distinct(n, budget.min(n));
                    pipeline.answer_with_rows(&chunks, &e.prompt, rows)?
                }
                "oracle" => {
                    // ground truth: the rows of the LAST occurrence of the
                    // queried key (the answer-bearing fact), padded with the
                    // rows right around it up to the budget
                    let flat: Vec<i32> = e.chunks.iter().flatten().copied().collect();
                    let qk = e.prompt[1];
                    let mut at = 0usize;
                    for i in 0..flat.len().saturating_sub(3) {
                        if flat[i] == vocab::KEYMARK && flat[i + 1] == qk {
                            at = i;
                        }
                    }
                    let lo = at.saturating_sub((budget - 5) / 2);
                    let rows: Vec<usize> = (lo..(lo + budget).min(n)).collect();
                    pipeline.answer_with_rows(&chunks, &e.prompt, rows)?
                }
                _ => unreachable!(),
            };
            f1 += token_f1(&r.answer, &e.answer);
            if r.selected
                .iter()
                .any(|&row| e.needle_chunks.contains(&(row / chunk)))
            {
                hits += 1;
            }
        }
        let f1 = f1 / samples as f64;
        let hit_rate = hits as f64 / samples as f64;
        println!("{variant:<9} f1={f1:.4} needle-hit={hit_rate:.2}");
        table.row(vec![variant.to_string(), fmt4(f1), format!("{hit_rate:.2}")]);
        json_rows.push(Json::obj(vec![
            ("selection", Json::from(variant)),
            ("f1", Json::from(f1)),
            ("needle_hit", Json::from(hit_rate)),
        ]));
    }
    println!("\n{}", table.render());
    ctx.dump("ablation", Json::Arr(json_rows), Some(table.to_csv()))?;
    Ok(())
}
