//! Shared setup for the reproduction harness: runtime + pipeline + store,
//! sample-count knobs, result dumping.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use crate::kvcache::ChunkStore;
use crate::pipeline::Pipeline;
use crate::runtime::exec::ModelSession;
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::json::Json;

pub struct BenchContext {
    pub runtime: Arc<Runtime>,
    /// Episodes per table cell (raise with --samples for tighter numbers).
    pub samples: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
}

impl BenchContext {
    pub fn from_args(args: &Args) -> Result<BenchContext> {
        let artifacts = args.get_or("artifacts", "artifacts");
        let runtime = Arc::new(Runtime::load(Path::new(artifacts))?);
        let out_dir = PathBuf::from(args.get_or("out", "results"));
        std::fs::create_dir_all(&out_dir)?;
        Ok(BenchContext {
            runtime,
            samples: args.usize_or("samples", 24)?,
            seed: args.u64_or("seed", 7)?,
            out_dir,
        })
    }

    pub fn pipeline(&self, backbone: &str) -> Result<Pipeline> {
        Pipeline::new(ModelSession::new(self.runtime.clone(), backbone)?)
    }

    pub fn store(&self) -> ChunkStore {
        ChunkStore::new(1 << 30)
    }

    /// First available backbone matching a preference list.
    pub fn backbone_or_default(&self, args: &Args) -> String {
        if let Some(b) = args.get("backbone") {
            return b.to_string();
        }
        let have = self.runtime.backbone_names();
        for want in ["qwen-syn", "base", "llama-syn"] {
            if have.iter().any(|h| h == want) {
                return want.to_string();
            }
        }
        have.first().cloned().unwrap_or_else(|| "qwen-syn".into())
    }

    pub fn dump(&self, name: &str, json: Json, csv: Option<String>) -> Result<()> {
        let jpath = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&jpath, json.to_string_pretty())?;
        if let Some(csv) = csv {
            std::fs::write(self.out_dir.join(format!("{name}.csv")), csv)?;
        }
        println!("[saved {}]", jpath.display());
        Ok(())
    }
}
